//! KV-migration experiment (beyond the paper's tables, quantifying its
//! §4.4 claim): carry live sequences' KV across a scaling event — remap
//! on surviving devices, P2P-copy off departing ones, recompute only when
//! cheaper — versus the legacy drain-and-recompute switchover that
//! re-prefills every in-flight context.
//!
//! Two scenarios under mid-stream long-context traffic (5000-token
//! prompts, decode in flight at the command):
//!
//! - **scale-up DP4→DP6**: every device group survives, so the handoff
//!   must be pure remap — zero prefill-recompute tokens.
//! - **scale-down DP4→DP3**: one replica departs; its long contexts copy
//!   over the fabric, and only cost-justified stragglers recompute.
//!
//! Reported per (scenario, policy): in-flight dispositions
//! (remap/copy/recompute), the recompute token bill, TTFT p99 over
//! requests arriving in the scaling window, and run-wide SLO attainment.
//! Expected shape: identical capacity timelines, but drain-and-recompute
//! pays a TTFT-p99 spike in the window (restarted sequences re-queue
//! behind their own re-prefills) that the migrating handoff avoids
//! entirely.

use anyhow::Result;

use crate::chaos::check_all;
use crate::config::model::dsv2_lite;
use crate::config::{ParallelConfig, SloConfig};
use crate::coordinator::{ServingSim, Trigger};
use crate::device::Timings;
use crate::engine::CostModel;
use crate::kvmigrate::{KvHandoffPolicy, KvHandoffStats};
use crate::scaling::ElasticMoE;
use crate::util::table::{f, Table};
use crate::workload::{RateProfile, Request, WorkloadGen, WorkloadSpec};

use super::common::elastic_with_opts;

const COMMAND_AT: f64 = 40.0;
const HORIZON: f64 = 160.0;
const PROMPT: usize = 5000;

fn cost() -> CostModel {
    CostModel::new(dsv2_lite(), Timings::cloudmatrix())
}

fn par(n: usize) -> Result<ParallelConfig> {
    super::common::par(&dsv2_lite(), n)
}

fn capacity(n: usize) -> f64 {
    cost().steady_throughput_rps(
        &par(n).unwrap(),
        64 << 30,
        PROMPT,
        200,
    )
}

fn workload_seeded(rps: f64, seed: u64, until: f64) -> Vec<Request> {
    let mut g = WorkloadGen::new(WorkloadSpec {
        prompt_len: PROMPT,
        decode_min: 150,
        decode_max: 250,
        profile: RateProfile::Fixed(rps),
        seed,
    });
    g.arrivals_until(until)
}

fn workload(rps: f64) -> Vec<Request> {
    workload_seeded(rps, 23, HORIZON)
}

fn method(policy: KvHandoffPolicy, cluster_n: usize) -> ElasticMoE {
    let mut e = elastic_with_opts(
        &dsv2_lite(),
        cluster_n,
        Default::default(),
        Default::default(),
    );
    e.kv_policy = policy;
    e
}

/// One (scenario, policy) run's measurements.
pub struct RunResult {
    pub scenario: &'static str,
    pub policy: &'static str,
    pub handoff: KvHandoffStats,
    /// TTFT p99 over requests arriving in the scaling window.
    pub ttft_p99_window: f64,
    pub attainment: f64,
    pub completed: usize,
}

/// Run one scenario under one policy. The workload is identical across
/// policies (same seed), so the TTFT comparison is apples-to-apples.
pub fn run_one(
    scenario: &'static str,
    from_n: usize,
    to_n: usize,
    rps: f64,
    policy: KvHandoffPolicy,
) -> Result<RunResult> {
    let slo = SloConfig::new(8.0, 1.5);
    let sim = ServingSim::new(cost(), slo);
    let mut m = method(policy, from_n.max(to_n));
    let out = sim.run(
        &mut m,
        &par(from_n)?,
        workload(rps),
        Trigger::Manual(vec![(COMMAND_AT, par(to_n)?)]),
        HORIZON,
    )?;
    // The window catches both the in-flight cohort (arrived while the
    // command landed mid-decode) and arrivals queued through the pause.
    let ttft_p99_window = out.recorder.ttft_percentile_by_arrival(
        COMMAND_AT - 20.0,
        COMMAND_AT + 20.0,
        99.0,
    );
    let w = out.recorder.window(0.0, out.end_time + 1.0, &slo);
    Ok(RunResult {
        scenario,
        policy: match policy {
            KvHandoffPolicy::Migrate => "remap+p2p",
            KvHandoffPolicy::DrainRecompute => "drain+recompute",
        },
        handoff: out.handoff,
        ttft_p99_window,
        attainment: w.slo_attainment,
        completed: w.completed,
    })
}

/// One seeded conformance run's summary: the live-handoff invariant
/// checkers' verdict plus the run digest.
pub struct ConformanceRun {
    pub completed: usize,
    pub handoff: KvHandoffStats,
    /// Invariant violations found by [`check_all`] (must be zero).
    pub violations: usize,
    /// The run's [`crate::coordinator::SimOutput::state_hash`] — equal
    /// across same-seed re-runs.
    pub state_hash: u64,
}

/// Run the canonical migrating scale-up (DP4→DP6 at 55% of the source
/// shape's capacity, command at t=40) for one seed, on a shortened
/// horizon, and return the invariant/violation summary plus the run
/// digest. Entry point for the seed-sweep determinism suite.
pub fn conformance_run(seed: u64) -> Result<ConformanceRun> {
    conformance_run_obs(seed, false)
}

/// [`conformance_run`] with the telemetry registry on or off: the
/// determinism suite runs both ways and asserts the digests are
/// bit-identical (telemetry must be a pure observer).
pub fn conformance_run_obs(seed: u64, obs: bool) -> Result<ConformanceRun> {
    const CONFORMANCE_HORIZON: f64 = 100.0;
    let rps = capacity(8) * 0.55;
    let slo = SloConfig::new(8.0, 1.5);
    let mut sim = ServingSim::new(cost(), slo);
    sim.obs = obs;
    let mut m = method(KvHandoffPolicy::Migrate, 12);
    let out = sim.run(
        &mut m,
        &par(8)?,
        workload_seeded(rps, seed, CONFORMANCE_HORIZON),
        Trigger::Manual(vec![(COMMAND_AT, par(12)?)]),
        CONFORMANCE_HORIZON,
    )?;
    let w = out.recorder.window(0.0, out.end_time + 1.0, &slo);
    Ok(ConformanceRun {
        completed: w.completed,
        handoff: out.handoff,
        violations: check_all(&out.trace).len(),
        state_hash: out.state_hash,
    })
}

/// All scenario × policy runs. `fast` keeps only the scale-up scenario.
pub fn compare(fast: bool) -> Result<Vec<RunResult>> {
    // Loads each target shape sustains: rising load for the scale-up,
    // falling for the scale-down.
    let up_rps = capacity(8) * 0.55;
    let down_rps = capacity(6) * 0.45;
    let mut runs = vec![
        run_one("up DP4→DP6", 8, 12, up_rps, KvHandoffPolicy::Migrate)?,
        run_one(
            "up DP4→DP6",
            8,
            12,
            up_rps,
            KvHandoffPolicy::DrainRecompute,
        )?,
    ];
    if !fast {
        runs.push(run_one(
            "down DP4→DP3",
            8,
            6,
            down_rps,
            KvHandoffPolicy::Migrate,
        )?);
        runs.push(run_one(
            "down DP4→DP3",
            8,
            6,
            down_rps,
            KvHandoffPolicy::DrainRecompute,
        )?);
    }
    Ok(runs)
}

/// `repro exp kvmigrate`.
pub fn run(opts: &super::common::ExpOptions) -> Result<String> {
    let fast = opts.fast;
    // `--trace-out`/`--metrics-out`: export telemetry from the canonical
    // migrating scale-up (the run whose span timeline shows the remap /
    // copy / switchover choreography).
    if opts.wants_obs() {
        let slo = SloConfig::new(8.0, 1.5);
        let mut sim = ServingSim::new(cost(), slo);
        sim.obs = true;
        let mut m = method(KvHandoffPolicy::Migrate, 12);
        let o = sim.run(
            &mut m,
            &par(8)?,
            workload(capacity(8) * 0.55),
            Trigger::Manual(vec![(COMMAND_AT, par(12)?)]),
            HORIZON,
        )?;
        opts.export_telemetry(o.telemetry.as_ref())?;
    }
    let runs = compare(fast)?;
    let mut table = Table::new(
        "KV migration: live-sequence handoff vs drain-and-recompute \
         (DSv2-Lite, command at t=40)",
    )
    .header([
        "scenario",
        "policy",
        "remap",
        "copy",
        "recompute",
        "recomp tok",
        "TTFT p99 (window)",
        "SLO%",
        "done",
    ]);
    for r in &runs {
        table.row([
            r.scenario.to_string(),
            r.policy.to_string(),
            r.handoff.remapped.to_string(),
            r.handoff.copied.to_string(),
            r.handoff.recomputed.to_string(),
            r.handoff.recompute_tokens.to_string(),
            f(r.ttft_p99_window, 2),
            f(r.attainment * 100.0, 1),
            r.completed.to_string(),
        ]);
    }
    let mut out = table.render();
    out.push_str(
        "\nExpected shape: under remap+p2p, scale-up recomputes zero \
         tokens (every device group survives) and scale-down copies its \
         long contexts instead of re-prefilling; drain+recompute restarts \
         every in-flight sequence, so its TTFT p99 over the scaling \
         window is strictly worse. Capacity timelines are identical — \
         the delta is pure switchover choreography.\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::PagedKv;
    use crate::kvmigrate::KvSnapshot;

    /// ISSUE acceptance (1): under ElasticMoE's migrating handoff, a
    /// scale-up event recomputes zero prefill tokens — every sequence's
    /// device group survives and is adopted in place.
    #[test]
    fn scale_up_is_zero_recompute_under_migrate() {
        let rps = capacity(8) * 0.55;
        let r = run_one("up", 8, 12, rps, KvHandoffPolicy::Migrate).unwrap();
        assert!(r.handoff.remapped > 0, "in-flight work must exist");
        assert_eq!(r.handoff.recomputed, 0);
        assert_eq!(r.handoff.recompute_tokens, 0);
        assert_eq!(r.handoff.lost_decode_tokens, 0);
        // The baseline on the same trace restarts that same cohort.
        let d =
            run_one("up", 8, 12, rps, KvHandoffPolicy::DrainRecompute)
                .unwrap();
        assert!(d.handoff.recomputed > 0);
        assert!(d.handoff.recompute_tokens > 0);
    }

    /// ISSUE acceptance (2): TTFT p99 across the scaling window is
    /// strictly lower with the migrating handoff, on both the scale-up
    /// and the scale-down.
    #[test]
    fn migrate_beats_drain_on_windowed_ttft_p99() {
        for (from_n, to_n, rps) in
            [(8usize, 12usize, capacity(8) * 0.55), (8, 6, capacity(6) * 0.45)]
        {
            let m = run_one("s", from_n, to_n, rps, KvHandoffPolicy::Migrate)
                .unwrap();
            let d = run_one(
                "s",
                from_n,
                to_n,
                rps,
                KvHandoffPolicy::DrainRecompute,
            )
            .unwrap();
            assert!(
                m.ttft_p99_window < d.ttft_p99_window,
                "{from_n}->{to_n}: migrate {} vs drain {}",
                m.ttft_p99_window,
                d.ttft_p99_window
            );
        }
    }

    /// ISSUE acceptance (3): KV bytes are conserved by the plan — blocks
    /// before the event = remapped + copied + freed — in both directions.
    #[test]
    fn kv_blocks_conserved_in_both_directions() {
        for (from_n, to_n) in [(8usize, 12usize), (8, 6)] {
            let mut m =
                method(KvHandoffPolicy::Migrate, from_n.max(to_n));
            use crate::scaling::ScalingMethod;
            m.boot(&par(from_n).unwrap()).unwrap();
            let mut pool = PagedKv::new(100_000, 16);
            for id in 0u64..12 {
                pool.admit(id, 3000 + 97 * id as usize).unwrap();
            }
            let snap = KvSnapshot::capture(&pool, &par(from_n).unwrap());
            let plan = m
                .hmm
                .plan_scale_with_kv(&par(to_n).unwrap(), Some(&snap))
                .unwrap();
            assert!(
                plan.kv_blocks_conserved(snap.total_blocks()),
                "{from_n}->{to_n}: {} != {} + {} + {}",
                snap.total_blocks(),
                plan.kv_remapped_blocks(),
                plan.kv_copied_blocks(),
                plan.kv_freed_blocks()
            );
        }
    }

    /// Scale-down moves the departing replica's contexts instead of
    /// recomputing them (they are long, so the copy is cheaper). Only
    /// sequences admitted *after* the plan was drawn may still restart
    /// (their blocks were never copied), so the recompute bill must be a
    /// small fraction of the drain baseline's, not merely smaller.
    #[test]
    fn scale_down_copies_instead_of_recomputing() {
        let rps = capacity(6) * 0.45;
        let r = run_one("down", 8, 6, rps, KvHandoffPolicy::Migrate).unwrap();
        let d = run_one("down", 8, 6, rps, KvHandoffPolicy::DrainRecompute)
            .unwrap();
        assert!(r.handoff.copied > 0, "departing contexts must copy");
        assert!(r.handoff.remapped > 0, "surviving contexts must remap");
        assert!(
            r.handoff.recompute_tokens * 4 < d.handoff.recompute_tokens,
            "migrate bill {} must be well under drain bill {}",
            r.handoff.recompute_tokens,
            d.handoff.recompute_tokens
        );
    }
}
