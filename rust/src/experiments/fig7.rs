//! Fig 7: scale-up latency across methods and models. The x-axis is
//! source->destination NPU transitions; infeasible baselines are omitted
//! exactly as in the paper (Extravagant needs src+dst fresh devices;
//! Horizontal only fires on exact doubling).

use anyhow::Result;

use crate::util::table::{f, Table};

use super::common::{
    display_name, make_method, par, par_on, paper_models, transitions,
    ExpOptions, METHODS,
};

pub fn run(opts: &ExpOptions) -> Result<String> {
    let fast = opts.fast;
    let mut out = String::new();
    let models = paper_models();
    let models = if fast { &models[..1] } else { &models[..] };
    for m in models {
        let mut table = Table::new(&format!(
            "Fig 7: scale-up latency (s) — {}",
            m.name
        ))
        .header(
            std::iter::once("transition".to_string()).chain(
                METHODS.iter().map(|s| display_name(s).to_string()),
            ),
        );
        for &(from_n, to_n) in &transitions(m) {
            let mut cells = vec![format!("{from_n}→{to_n}")];
            for &name in METHODS {
                let cell = match scale_latency(name, m, from_n, to_n) {
                    Ok(Some(t)) => f(t, 2),
                    Ok(None) => "—".to_string(),
                    Err(e) => {
                        log::debug!("{name} {from_n}->{to_n}: {e}");
                        "—".to_string()
                    }
                };
                cells.push(cell);
            }
            table.row(cells);
        }
        out.push_str(&table.render());
        out.push('\n');
    }
    out.push_str(
        "Expected shape: ElasticMoE ≈0.1x the best baseline on every \
         transition (paper: ≈0.11x, 80.9% improvement).\n",
    );
    Ok(out)
}

/// Run one (method, model, transition); None = infeasible (omitted bar).
pub fn scale_latency(
    method: &str,
    m: &crate::config::ModelConfig,
    from_n: usize,
    to_n: usize,
) -> Result<Option<f64>> {
    match method {
        "horizontal" => {
            // Feasible only when resources are exactly doubled.
            if to_n != 2 * from_n {
                return Ok(None);
            }
            let mut meth = make_method(method, m, 2 * from_n)?;
            meth.boot(&par(m, from_n)?)?;
            let out = meth.scale(&par_on(m, from_n..2 * from_n)?)?;
            Ok(Some(out.ready_after))
        }
        "extravagant" => {
            // Needs src+dst simultaneously.
            let mut meth = make_method(method, m, from_n + to_n)?;
            meth.boot(&par(m, from_n)?)?;
            let out = meth.scale(&par_on(m, from_n..from_n + to_n)?)?;
            Ok(Some(out.ready_after))
        }
        _ => {
            let mut meth = make_method(method, m, to_n.max(from_n))?;
            meth.boot(&par(m, from_n)?)?;
            let out = meth.scale(&par(m, to_n)?)?;
            Ok(Some(out.ready_after))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::dsv2_lite;

    #[test]
    fn elastic_is_order_of_magnitude_faster() {
        let m = dsv2_lite();
        let e = scale_latency("elastic", &m, 4, 6).unwrap().unwrap();
        let c = scale_latency("cold", &m, 4, 6).unwrap().unwrap();
        let x = scale_latency("extravagant", &m, 4, 6).unwrap().unwrap();
        let best_baseline = c.min(x);
        assert!(
            e / best_baseline < 0.2,
            "elastic {e} vs best baseline {best_baseline}"
        );
    }

    #[test]
    fn horizontal_only_on_doubling() {
        let m = dsv2_lite();
        assert!(scale_latency("horizontal", &m, 4, 6)
            .unwrap()
            .is_none());
        assert!(scale_latency("horizontal", &m, 4, 8)
            .unwrap()
            .is_some());
    }

    #[test]
    fn dsv3_large_jumps_run() {
        let m = crate::config::model::dsv3();
        let e = scale_latency("elastic", &m, 32, 48).unwrap().unwrap();
        let c = scale_latency("cold", &m, 32, 48).unwrap().unwrap();
        assert!(e < c, "elastic {e} cold {c}");
    }
}
