//! Fig 11: latency breakdown of an ElasticMoE scale-up (Qwen3-30B-A3B,
//! 12->16 NPUs). Warmup should dominate; data movement and zero-copy reuse
//! should be marginal.

use anyhow::Result;

use crate::config::model::qwen30b;
use crate::hmm::control::HmmOptions;
use crate::imm::manager::ImmOptions;
use crate::util::table::{f, Table};

use super::common::{elastic_with_opts, par};
use crate::scaling::ScalingMethod;

pub fn run() -> Result<String> {
    let m = qwen30b();
    let mut meth = elastic_with_opts(
        &m,
        16,
        HmmOptions::default(),
        ImmOptions::default(),
    );
    meth.boot(&par(&m, 12)?)?;
    let out = meth.scale(&par(&m, 16)?)?;

    let mut table = Table::new(
        "Fig 11: ElasticMoE scale-up latency breakdown — qwen30b 12→16",
    )
    .header(["stage", "seconds", "% of total"]);
    let total = out.ready_after.max(1e-12);
    for (name, t) in &out.metrics.stages {
        table.row([
            name.clone(),
            f(*t, 3),
            f(100.0 * t / total, 1),
        ]);
    }
    table.row(["TOTAL (critical path)".into(), f(total, 3), "100".into()]);
    let mut s = table.render();
    s.push_str(
        "\nExpected shape: warmup (~4.2 s) dominates; P2P transfers, \
         zero-copy mapping and KV reuse add at most a couple of seconds \
         combined (the reconfiguration machinery is nearly free).\n",
    );
    Ok(s)
}

#[cfg(test)]
mod tests {
    #[test]
    fn warmup_dominates() {
        let report = super::run().unwrap();
        assert!(report.contains("warmup"));
        // Extract the warmup percentage row and assert > 40%.
        let line = report
            .lines()
            .find(|l| l.starts_with("warmup"))
            .unwrap();
        let pct: f64 = line
            .split_whitespace()
            .last()
            .unwrap()
            .parse()
            .unwrap();
        assert!(pct > 40.0, "warmup only {pct}% of scale-up");
    }
}
