//! Fig 4: (a) instance cold-initialisation latency breakdown; (b)
//! per-device weight memory across EP degrees — the two motivating
//! measurements behind insights L1 and L4.

use anyhow::Result;

use crate::device::Cluster;
use crate::scaling::boot::cold_boot;
use crate::util::table::{f, Table};
use crate::util::fmt_bytes;

use super::common::{par, paper_models, KV_BYTES};

pub fn fig4a() -> Result<String> {
    let mut table = Table::new(
        "Fig 4a: cold instance initialisation latency breakdown (s)",
    )
    .header([
        "model", "devices", "container", "preinit", "comm_init",
        "weight_load", "kv_alloc", "warmup", "TOTAL",
    ]);
    for m in paper_models() {
        let n = m.min_devices;
        let mut cluster = Cluster::cloudmatrix(n);
        let p = par(&m, n)?;
        let (_regions, b) =
            cold_boot(&mut cluster, &m, &p, KV_BYTES, 1)?;
        table.row([
            m.name.to_string(),
            n.to_string(),
            f(b.container, 1),
            f(b.preinit, 1),
            f(b.comm_init, 1),
            f(b.weight_load, 1),
            f(b.kv_alloc, 1),
            f(b.warmup, 1),
            f(b.total(), 1),
        ]);
    }
    let mut out = table.render();
    out.push_str(
        "\nExpected shape: totals of tens of seconds to minutes, growing \
         with model size and device count; weight loading and engine \
         pre-init dominate (the costs ElasticMoE's HMM/IMM eliminate).\n",
    );
    Ok(out)
}

pub fn fig4b() -> Result<String> {
    let mut out = String::new();
    for m in paper_models() {
        let mut table = Table::new(&format!(
            "Fig 4b: per-device weight memory vs EP — {}",
            m.name
        ))
        .header(["EP degree", "weights/device", "experts/device"]);
        for ep in [2usize, 4, 8, 16, 32, 64, 128, 256] {
            if ep > m.n_experts as usize {
                continue;
            }
            let bytes = m.device_weight_bytes(m.tp, ep);
            table.row([
                format!("EP{ep}"),
                fmt_bytes(bytes),
                format!("{}", (m.n_experts as usize).div_ceil(ep)),
            ]);
        }
        out.push_str(&table.render());
        out.push('\n');
    }
    out.push_str(
        "Expected shape: monotonically decreasing — replicating experts in \
         small isolated instances (low EP) wastes HBM that higher EP \
         degrees return to the KV cache.\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    #[test]
    fn reports_render() {
        let a = super::fig4a().unwrap();
        assert!(a.contains("dsv2lite") && a.contains("TOTAL"));
        let b = super::fig4b().unwrap();
        assert!(b.contains("EP64"));
    }
}
