//! Control-plane reconcile conformance (beyond the paper's tables):
//! drive the fleet's declared-spec vs observed-state reconciler through
//! a flash crowd while control-plane faults fire, and machine-check
//! convergence on every cell.
//!
//! Every cell runs the **same** seeded burst trace on the same hybrid
//! fleet — only the fault plan differs: none, heartbeat loss (a serving
//! replica goes silent long enough to be evicted and its spec slot
//! re-planned), a stale observed snapshot (the reconciler plans against
//! the previous round's state for several ticks), and duplicate command
//! enactment (whole step batches replayed twice). Each cell must satisfy
//! the full invariant catalog ([`crate::chaos::invariants`]) including
//! reconcile convergence: once faults stop firing, spec drift must reach
//! zero within [`crate::chaos::CONVERGENCE_ROUNDS`] reconcile rounds.
//! The duplicate cell must additionally match the fault-free cell's
//! applied-action log exactly — replays are checked no-ops, never second
//! mutations. Any violation aborts the experiment with the seed needed
//! to replay it (`repro exp reconcile --seed N`). See
//! `docs/architecture/09-control-plane.md`.

use std::cell::RefCell;
use std::rc::Rc;

use anyhow::{bail, Result};

use crate::chaos::{
    check_all, FaultEntry, FaultInjector, FaultKind, FaultPlan,
    TraceEvent, Violation,
};
use crate::config::model::dsv2_lite;
use crate::config::SloConfig;
use crate::coordinator::{
    FleetAction, FleetLimits, FleetOutput, FleetPolicy, FleetSim,
    PolicyMode, Router,
};
use crate::device::Timings;
use crate::engine::CostModel;
use crate::hmm::control::HmmOptions;
use crate::imm::manager::ImmOptions;
use crate::scaling::ScalingMethod;
use crate::util::table::Table;
use crate::workload::{RateProfile, Request, WorkloadGen, WorkloadSpec};

use super::common::elastic_with_opts;

/// Default seed when `--seed` is not given.
pub const DEFAULT_SEED: u64 = 23;

const REPLICA_MAX: usize = 8;

fn limits() -> FleetLimits {
    FleetLimits {
        pool_devices: 12,
        replica_base: 2,
        replica_max: REPLICA_MAX,
        step: 2,
        min_replicas: 2,
    }
}

fn policy() -> FleetPolicy {
    let mut p = FleetPolicy::new(
        PolicyMode::Hybrid,
        limits(),
        SloConfig::scale_up_demo(),
    );
    p.estimator.up_patience = 1;
    p.estimator.cooldown = 10.0;
    p.replica_cooldown = 10.0;
    p
}

fn elastic_factory(
) -> impl FnMut(usize) -> Result<Box<dyn ScalingMethod>> {
    move |_| {
        Ok(Box::new(elastic_with_opts(
            &dsv2_lite(),
            REPLICA_MAX,
            HmmOptions::default(),
            ImmOptions::default(),
        )) as Box<dyn ScalingMethod>)
    }
}

fn workload(seed: u64, fast: bool) -> Vec<Request> {
    let horizon = horizon(fast);
    let mut g = WorkloadGen::new(WorkloadSpec {
        prompt_len: 2000,
        decode_min: 100,
        decode_max: 150,
        profile: RateProfile::Burst {
            base: 0.8,
            factor: 10.0,
            start: 60.0,
            len: if fast { 30.0 } else { 45.0 },
        },
        seed,
    });
    g.arrivals_until(horizon)
}

fn horizon(fast: bool) -> f64 {
    if fast {
        120.0
    } else {
        180.0
    }
}

/// Map a fault name to its plan. The seed perturbs the target replica,
/// the silence window and the stale-snapshot round so repeated runs
/// probe different abort points, all reproducible from the printed seed.
fn fault_plan(name: &str, seed: u64) -> FaultPlan {
    match name {
        "none" => FaultPlan::none(),
        // A serving replica goes silent for the rest of the run: its
        // staleness must cross the eviction deadline at some
        // non-transitioning tick no matter how the burst lands.
        "heartbeat-loss" => FaultPlan::single(
            4 + (seed % 4) as usize,
            FaultKind::HeartbeatLoss {
                replica: (seed % 2) as usize,
                beats: 60,
            },
        ),
        // The reconciler sees the previous round's snapshot across the
        // burst onset, exactly when the spec is moving fastest.
        "stale-observed" => FaultPlan::single(
            10 + (seed % 2) as usize,
            FaultKind::StaleObservedState { ticks: 3 + (seed % 3) as usize },
        ),
        // Replay whole step batches across the burst ramp.
        "duplicate-command" => FaultPlan {
            entries: (8..24)
                .map(|r| FaultEntry {
                    event: r,
                    kind: FaultKind::DuplicateCommand,
                })
                .collect(),
        },
        other => panic!("unknown control-plane fault '{other}'"),
    }
}

/// One cell's measurements.
struct CellResult {
    fault: &'static str,
    arrived: usize,
    completed: usize,
    fault_fired: bool,
    missed: usize,
    evictions: usize,
    applied_steps: usize,
    noop_steps: usize,
    max_drift: usize,
    violations: Vec<Violation>,
    actions: Vec<(f64, FleetAction)>,
    state_hash: u64,
    telemetry: Option<crate::obs::Telemetry>,
}

fn count(out: &FleetOutput, pred: impl Fn(&TraceEvent) -> bool) -> usize {
    out.trace.events.iter().filter(|e| pred(e)).count()
}

/// Run one fault cell on the seeded flash-crowd trace.
fn run_cell(
    fault: &'static str,
    seed: u64,
    fast: bool,
) -> Result<CellResult> {
    run_cell_obs(fault, seed, fast, false)
}

/// [`run_cell`] with the telemetry registry optionally enabled (exports
/// reconciler spans and the `fleet/spec_drift` series).
fn run_cell_obs(
    fault: &'static str,
    seed: u64,
    fast: bool,
    obs: bool,
) -> Result<CellResult> {
    let mut sim = FleetSim::new(
        CostModel::new(dsv2_lite(), Timings::cloudmatrix()),
        SloConfig::scale_up_demo(),
        Router::JoinShortestQueue,
    );
    sim.obs = obs;
    let inj = Rc::new(RefCell::new(FaultInjector::new(fault_plan(
        fault, seed,
    ))));
    sim.injector = Some(inj.clone());
    let mut policy = policy();
    let arrivals = workload(seed, fast);
    let arrived = arrivals.len();
    let out = sim.run(
        &mut policy,
        &mut elastic_factory(),
        2,
        arrivals,
        horizon(fast),
    )?;

    let violations = check_all(&out.trace);
    Ok(CellResult {
        fault,
        arrived,
        completed: out.recorder.count(),
        fault_fired: count(&out, |e| {
            matches!(e, TraceEvent::FaultFired { .. })
        }) > 0,
        missed: count(&out, |e| {
            matches!(e, TraceEvent::HeartbeatMissed { .. })
        }),
        evictions: count(&out, |e| {
            matches!(e, TraceEvent::ReplicaEvicted { .. })
        }),
        applied_steps: count(&out, |e| {
            matches!(e, TraceEvent::ReconcileStep { applied: true, .. })
        }),
        noop_steps: count(&out, |e| {
            matches!(e, TraceEvent::ReconcileStep { applied: false, .. })
        }),
        max_drift: out
            .trace
            .events
            .iter()
            .filter_map(|e| match e {
                TraceEvent::SpecDeclared { drift, .. } => Some(*drift),
                _ => None,
            })
            .max()
            .unwrap_or(0),
        violations,
        actions: out.actions,
        state_hash: out.state_hash,
        telemetry: out.telemetry,
    })
}

/// The decision-ledger leg for `repro report`: the duplicate-command
/// cell run with full instrumentation. This cell is the one place in
/// the repo where the estimator, the reconciler guards and the fault
/// injector all fire on one trace — its [`FleetOutput`] carries
/// `DecisionExplain` records from every policy tick *and* checked
/// no-op `ReconcileStep { applied: false }` marks (guaranteed `>= 1`
/// by the reconcile experiment's own acceptance), so the rendered
/// ledger always shows at least one guard-vetoed entry.
pub fn ledger_run(
    seed: u64,
    fast: bool,
) -> Result<(FleetOutput, Vec<Violation>)> {
    ledger_run_obs(seed, fast, true)
}

/// [`ledger_run`] with the telemetry registry switchable — the
/// determinism suite runs it both ways to pin that `DecisionExplain`
/// emission is unconditional and the `state_hash` telemetry-neutral.
pub fn ledger_run_obs(
    seed: u64,
    fast: bool,
    obs: bool,
) -> Result<(FleetOutput, Vec<Violation>)> {
    let mut sim = FleetSim::new(
        CostModel::new(dsv2_lite(), Timings::cloudmatrix()),
        SloConfig::scale_up_demo(),
        Router::JoinShortestQueue,
    );
    sim.obs = obs;
    let inj = Rc::new(RefCell::new(FaultInjector::new(fault_plan(
        "duplicate-command",
        seed,
    ))));
    sim.injector = Some(inj);
    let mut policy = policy();
    let arrivals = workload(seed, fast);
    let out = sim.run(
        &mut policy,
        &mut elastic_factory(),
        2,
        arrivals,
        horizon(fast),
    )?;
    let violations = check_all(&out.trace);
    Ok((out, violations))
}

/// The SLO the ledger leg is judged against (shared with
/// [`crate::report`]).
pub fn report_slo() -> SloConfig {
    SloConfig::scale_up_demo()
}

/// One cell of [`conformance`]: the fields the determinism sweep
/// (`rust/tests/determinism.rs`) compares across seeds and re-runs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConformanceCell {
    pub fault: &'static str,
    pub arrived: usize,
    pub completed: usize,
    pub evictions: usize,
    pub noop_steps: usize,
    /// Invariant violations found by [`check_all`] (must be zero).
    pub violations: usize,
    /// The run's [`FleetOutput::state_hash`] — equal across same-seed
    /// re-runs.
    pub state_hash: u64,
}

/// Run the control-plane fault matrix for one seed and return every
/// cell's conformance summary plus its run digest. Entry point for the
/// seed-sweep determinism suite.
pub fn conformance(seed: u64) -> Result<Vec<ConformanceCell>> {
    conformance_with_obs(seed, false)
}

/// [`conformance`] with the telemetry registry on or off: the
/// determinism suite runs each cell both ways and asserts the digests
/// are bit-identical (telemetry must be a pure observer).
pub fn conformance_with_obs(
    seed: u64,
    obs: bool,
) -> Result<Vec<ConformanceCell>> {
    let mut cells = Vec::new();
    for fault in matrix() {
        let r = run_cell_obs(fault, seed, true, obs)?;
        cells.push(ConformanceCell {
            fault: r.fault,
            arrived: r.arrived,
            completed: r.completed,
            evictions: r.evictions,
            noop_steps: r.noop_steps,
            violations: r.violations.len(),
            state_hash: r.state_hash,
        });
    }
    Ok(cells)
}

/// The fault matrix: the fault-free baseline plus the three
/// control-plane faults, all on the identical trace.
fn matrix() -> [&'static str; 4] {
    ["none", "heartbeat-loss", "stale-observed", "duplicate-command"]
}

/// Per-cell acceptance: zero invariant violations (including reconcile
/// convergence), everything served exactly once, and the fault actually
/// exercised its failure mode.
fn assert_cell(r: &CellResult, seed: u64) -> Result<()> {
    if !r.violations.is_empty() {
        bail!(
            "cell [{}] violated {} invariant(s) (replay with \
             `repro exp reconcile --seed {seed}`): {}",
            r.fault,
            r.violations.len(),
            r.violations
                .iter()
                .map(|v| v.to_string())
                .collect::<Vec<_>>()
                .join("; ")
        );
    }
    if r.completed != r.arrived {
        bail!(
            "cell [{}]: {} of {} requests completed (seed {seed})",
            r.fault,
            r.completed,
            r.arrived
        );
    }
    if r.fault != "none" && !r.fault_fired {
        bail!("cell [{}]: fault never fired (seed {seed})", r.fault);
    }
    match r.fault {
        "heartbeat-loss" => {
            if r.missed == 0 || r.evictions == 0 {
                bail!(
                    "cell [heartbeat-loss]: silence must surface as missed \
                     beats and an eviction (missed {}, evicted {}, seed \
                     {seed})",
                    r.missed,
                    r.evictions
                );
            }
        }
        "duplicate-command" => {
            if r.noop_steps == 0 {
                bail!(
                    "cell [duplicate-command]: replays must leave checked \
                     no-op marks (seed {seed})"
                );
            }
        }
        _ => {}
    }
    Ok(())
}

/// `repro exp reconcile [--seed N]`.
pub fn run(opts: &super::common::ExpOptions) -> Result<String> {
    let fast = opts.fast;
    let seed = opts.seed_or(DEFAULT_SEED);
    let mut results = Vec::new();
    for (i, fault) in matrix().into_iter().enumerate() {
        let obs = i == 0 && opts.wants_obs();
        let r = run_cell_obs(fault, seed, fast, obs)?;
        if obs {
            opts.export_telemetry(r.telemetry.as_ref())?;
        }
        assert_cell(&r, seed)?;
        results.push(r);
    }

    // Duplicate enactment must be invisible in the applied-action log:
    // same trace, same decisions, every replay a checked no-op.
    let none = &results[0];
    let dup = results
        .iter()
        .find(|r| r.fault == "duplicate-command")
        .expect("matrix has the duplicate cell");
    if dup.actions != none.actions {
        bail!(
            "duplicate-command cell diverged from the fault-free \
             action log ({} vs {} actions, seed {seed})",
            dup.actions.len(),
            none.actions.len()
        );
    }
    if none.noop_steps != 0 {
        bail!(
            "fault-free cell must have no no-op steps, got {} (seed \
             {seed})",
            none.noop_steps
        );
    }

    let mut table = Table::new(
        "Reconcile conformance: control-plane faults on one flash-crowd \
         trace, convergence invariant checked per cell",
    )
    .header([
        "fault",
        "done",
        "missed",
        "evicted",
        "applied",
        "no-op",
        "max drift",
        "violations",
    ]);
    for r in &results {
        table.row([
            r.fault.to_string(),
            format!("{}/{}", r.completed, r.arrived),
            r.missed.to_string(),
            r.evictions.to_string(),
            r.applied_steps.to_string(),
            r.noop_steps.to_string(),
            r.max_drift.to_string(),
            r.violations.len().to_string(),
        ]);
    }
    let mut out = table.render();
    out.push_str(&format!(
        "\nseed {seed} — every cell converged to the declared spec \
         within {} reconcile rounds of the last fault, served its full \
         trace exactly once, and the duplicate cell's applied-action \
         log matched the fault-free run. Replay with `repro exp \
         reconcile --seed {seed}`.\n",
        crate::chaos::CONVERGENCE_ROUNDS
    ));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// ISSUE acceptance: every control-plane fault cell converges to the
    /// declared spec within bounded reconcile rounds with zero
    /// invariant violations, and the summary is deterministic across
    /// re-runs of the same seed.
    #[test]
    fn fault_matrix_converges_and_is_deterministic() {
        let a = conformance(DEFAULT_SEED).unwrap();
        for cell in &a {
            assert_eq!(cell.violations, 0, "{cell:?}");
            assert_eq!(cell.completed, cell.arrived, "{cell:?}");
        }
        let hb = a.iter().find(|c| c.fault == "heartbeat-loss").unwrap();
        assert!(hb.evictions >= 1, "silence must evict");
        let dup =
            a.iter().find(|c| c.fault == "duplicate-command").unwrap();
        assert!(dup.noop_steps >= 1, "replays must be traced no-ops");
        let b = conformance(DEFAULT_SEED).unwrap();
        assert_eq!(a, b, "conformance summary must be reproducible");
    }
}
