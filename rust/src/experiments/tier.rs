//! Tiered weight-store experiment (beyond the paper): serverless-style
//! scale-to-zero on an on/off bursty trace.
//!
//! One 2-device DSv2-Lite replica faces a trace that bursts for ~45 s,
//! goes silent for ~100 s, and repeats — the serverless pattern MoEless
//! (arXiv 2603.06350) targets. Three provisioning strategies run the
//! identical trace:
//!
//! - **always-on** — the min-replica baseline: the replica never
//!   releases its devices. Best latency, worst HBM-hours.
//! - **disk-cold** — park/unpark with no DRAM tier: parking drops the
//!   weights to disk, so every wake-up is a full cold boot (container +
//!   pre-init + disk load + warmup).
//! - **dram-warm** — the tiered store: parking demotes weights to host
//!   DRAM; waking pays host-restore + h2d + attach + warmup.
//!
//! Acceptance (asserted here and in the in-module tests):
//! 1. DRAM-warm unpark is strictly faster than disk cold boot on the
//!    same configuration;
//! 2. park/unpark strictly beats always-on on HBM device-seconds
//!    without losing SLO attainment on the bursty trace;
//! 3. tier residency bytes conserve across every demote/promote/park
//!    event — the [`crate::chaos::check_tier_conservation`] invariant
//!    over the run's trace, reconciling the journal against the
//!    host-DRAM allocator.

use anyhow::{bail, Result};

use crate::chaos::{check_all, Violation};
use crate::config::model::dsv2_lite;
use crate::config::SloConfig;
use crate::coordinator::{
    FleetLimits, FleetPolicy, FleetSim, PolicyMode, Router,
};
use crate::device::Timings;
use crate::engine::CostModel;
use crate::scaling::{ElasticMoE, ScalingMethod};
use crate::tier::{
    pipelined_promote_time, sequential_stage_time, warm_promote_time,
};
use crate::util::table::{f, Table};
use crate::workload::{RateProfile, Request, WorkloadGen, WorkloadSpec};

use super::common::{elastic_with_opts, par, ExpOptions};

/// Default workload seed (`--seed` overrides).
pub const DEFAULT_SEED: u64 = 7;

const REPLICA_DEVICES: usize = 2;
const FIRST_BURST: f64 = 20.0;
const BURST_LEN: f64 = 45.0;
const PERIOD: f64 = 150.0;

fn cost() -> CostModel {
    CostModel::new(dsv2_lite(), Timings::cloudmatrix())
}

fn slo() -> SloConfig {
    // TTFT budget wide enough to absorb a DRAM-warm wake-up (seconds),
    // but far under a disk cold boot (a minute-class gap).
    SloConfig::new(15.0, 2.0)
}

fn cycles(fast: bool) -> usize {
    if fast {
        2
    } else {
        3
    }
}

fn horizon(fast: bool) -> f64 {
    FIRST_BURST + cycles(fast) as f64 * PERIOD
}

/// The on/off trace: `cycles` bursts of Poisson traffic at ~50% of the
/// replica's steady capacity, separated by dead-silent gaps.
fn bursty_trace(fast: bool, seed: u64) -> Vec<Request> {
    let rps = cost().steady_throughput_rps(
        &par(&dsv2_lite(), REPLICA_DEVICES).unwrap(),
        64 << 30,
        2000,
        120,
    ) * 0.5;
    let mut out = Vec::new();
    for cycle in 0..cycles(fast) {
        let start = FIRST_BURST + cycle as f64 * PERIOD;
        let mut g = WorkloadGen::new(WorkloadSpec {
            prompt_len: 2000,
            decode_min: 80,
            decode_max: 140,
            profile: RateProfile::Fixed(rps),
            seed: seed ^ (cycle as u64 + 1),
        });
        for mut r in g.arrivals_until(BURST_LEN) {
            r.id += cycle as u64 * 1_000_000;
            r.arrival += start;
            out.push(r);
        }
    }
    out
}

/// Park strategy of one cell.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Strategy {
    AlwaysOn,
    DiskCold,
    DramWarm,
}

impl Strategy {
    fn label(self) -> &'static str {
        match self {
            Strategy::AlwaysOn => "always-on",
            Strategy::DiskCold => "disk-cold park",
            Strategy::DramWarm => "dram-warm park",
        }
    }
}

struct CellResult {
    strategy: Strategy,
    arrived: usize,
    completed: usize,
    truncated: usize,
    attainment: f64,
    device_seconds: f64,
    parks: usize,
    unparks: usize,
    mean_unpark: f64,
    violations: Vec<Violation>,
}

fn run_cell(strategy: Strategy, fast: bool, seed: u64) -> Result<CellResult> {
    let sim = FleetSim::new(cost(), slo(), Router::JoinShortestQueue);
    let limits = FleetLimits {
        pool_devices: REPLICA_DEVICES,
        replica_base: REPLICA_DEVICES,
        replica_max: REPLICA_DEVICES, // no vertical envelope: isolate park
        step: REPLICA_DEVICES,
        min_replicas: 1,
    };
    let mut policy = FleetPolicy::new(PolicyMode::Hybrid, limits, slo());
    policy.estimator.up_patience = 1;
    policy.estimator.down_patience = 3;
    policy.estimator.cooldown = 10.0;
    policy.replica_cooldown = 10.0;
    policy.park_enabled = strategy != Strategy::AlwaysOn;
    policy.park_ttl = PERIOD * 1.5;

    let mut factory = |_i: usize| -> Result<Box<dyn ScalingMethod>> {
        let mut e: ElasticMoE = elastic_with_opts(
            &dsv2_lite(),
            REPLICA_DEVICES,
            Default::default(),
            Default::default(),
        );
        e.park_warm = strategy == Strategy::DramWarm;
        Ok(Box::new(e))
    };

    let arrivals = bursty_trace(fast, seed);
    let arrived = arrivals.len();
    let h = horizon(fast);
    let out = sim.run(&mut policy, &mut factory, 1, arrivals, h)?;

    let mean_unpark = if out.unpark_boots.is_empty() {
        0.0
    } else {
        out.unpark_boots.iter().map(|&(_, b)| b).sum::<f64>()
            / out.unpark_boots.len() as f64
    };
    Ok(CellResult {
        strategy,
        arrived,
        completed: out.recorder.count(),
        truncated: out.truncated,
        attainment: out.recorder.attainment_by_arrival(0.0, h, &slo()),
        device_seconds: out.device_seconds(),
        parks: out.count_actions(|a| {
            matches!(a, crate::coordinator::FleetAction::Park { .. })
        }),
        unparks: out.unpark_boots.len(),
        mean_unpark,
        violations: check_all(&out.trace),
    })
}

/// Direct method-level unpark latency, outside the fleet loop: the same
/// parked configuration woken DRAM-warm vs disk-cold.
fn unpark_latency(warm: bool) -> Result<f64> {
    let mut e: ElasticMoE = elastic_with_opts(
        &dsv2_lite(),
        REPLICA_DEVICES,
        Default::default(),
        Default::default(),
    );
    e.park_warm = warm;
    e.boot(&par(&dsv2_lite(), REPLICA_DEVICES)?)?;
    e.park()?
        .ok_or_else(|| anyhow::anyhow!("park unsupported"))?;
    e.unpark()?
        .ok_or_else(|| anyhow::anyhow!("unpark unsupported"))
}

/// `repro exp tier [--fast] [--seed N]`.
pub fn run(opts: &ExpOptions) -> Result<String> {
    let fast = opts.fast;
    let seed = opts.seed_or(DEFAULT_SEED);

    // Acceptance 1 — method-level: DRAM-warm unpark strictly beats a
    // disk cold boot on the same configuration.
    let warm_unpark = unpark_latency(true)?;
    let cold_unpark = unpark_latency(false)?;
    if warm_unpark >= cold_unpark {
        bail!(
            "DRAM-warm unpark {warm_unpark:.2}s must beat disk-cold \
             {cold_unpark:.2}s (seed {seed})"
        );
    }

    let mut cells = Vec::new();
    for strategy in
        [Strategy::AlwaysOn, Strategy::DiskCold, Strategy::DramWarm]
    {
        let r = run_cell(strategy, fast, seed)?;
        if !r.violations.is_empty() {
            bail!(
                "cell [{}] violated {} trace invariant(s) (replay with \
                 `repro exp tier --seed {seed}`): {}",
                r.strategy.label(),
                r.violations.len(),
                r.violations
                    .iter()
                    .map(|v| v.to_string())
                    .collect::<Vec<_>>()
                    .join("; ")
            );
        }
        if r.truncated != 0 || r.completed != r.arrived {
            bail!(
                "cell [{}]: {}/{} served, {} truncated (seed {seed})",
                r.strategy.label(),
                r.completed,
                r.arrived,
                r.truncated
            );
        }
        cells.push(r);
    }
    let find = |s: Strategy| cells.iter().find(|c| c.strategy == s).unwrap();
    let always = find(Strategy::AlwaysOn);
    let warm = find(Strategy::DramWarm);
    let cold = find(Strategy::DiskCold);

    // Acceptance 2 — fleet-level: park/unpark strictly beats always-on
    // on HBM device-seconds without losing SLO attainment.
    if warm.parks == 0 || warm.unparks == 0 {
        bail!(
            "dram-warm cell must park and unpark (parks {}, unparks {}, \
             seed {seed})",
            warm.parks,
            warm.unparks
        );
    }
    if warm.device_seconds >= always.device_seconds {
        bail!(
            "park/unpark must strictly beat always-on on HBM-hours: \
             {:.0} vs {:.0} device-seconds (seed {seed})",
            warm.device_seconds,
            always.device_seconds
        );
    }
    if warm.attainment + 0.02 < always.attainment {
        bail!(
            "park/unpark must not lose SLO attainment: {:.3} vs \
             always-on {:.3} (seed {seed})",
            warm.attainment,
            always.attainment
        );
    }
    // Shape check: cold wake-ups are the ones that hurt.
    if cold.unparks > 0 && cold.mean_unpark <= warm.mean_unpark {
        bail!(
            "disk-cold unpark {:.2}s must exceed dram-warm {:.2}s \
             (seed {seed})",
            cold.mean_unpark,
            warm.mean_unpark
        );
    }

    let mut table = Table::new(
        "Tiered weight store: on/off bursty trace (DSv2-Lite, 2-device \
         replica, ~45 s bursts / ~105 s gaps)",
    )
    .header([
        "strategy",
        "done",
        "SLO%",
        "dev-seconds",
        "parks",
        "unparks",
        "mean unpark (s)",
        "violations",
    ]);
    for c in &cells {
        table.row([
            c.strategy.label().to_string(),
            format!("{}/{}", c.completed, c.arrived),
            f(c.attainment * 100.0, 1),
            f(c.device_seconds, 0),
            c.parks.to_string(),
            c.unparks.to_string(),
            if c.unparks == 0 {
                "-".to_string()
            } else {
                f(c.mean_unpark, 2)
            },
            c.violations.len().to_string(),
        ]);
    }
    let mut out = table.render();

    // The boot-path ladder on identical fresh clusters: the baselines'
    // disk cold boot vs the DRAM-warm boot the unpark path rides.
    let m = dsv2_lite();
    let p = par(&m, REPLICA_DEVICES)?;
    let mut c1 = crate::device::Cluster::cloudmatrix(REPLICA_DEVICES);
    let (_, cold_b) =
        crate::scaling::boot::cold_boot(&mut c1, &m, &p, 8 << 30, 1)?;
    let mut c2 = crate::device::Cluster::cloudmatrix(REPLICA_DEVICES);
    let (_, warm_b) =
        crate::scaling::boot::dram_warm_boot(&mut c2, &m, &p, 8 << 30, 2)?;

    // The staging pipeline micro-model: what the background prefetch
    // buys over sequential staging, and what DRAM-warmth buys over both.
    let t = Timings::cloudmatrix();
    let units: Vec<u64> = vec![m.expert_bytes(); 64];
    out.push_str(&format!(
        "\nunpark latency: dram-warm {warm_unpark:.2}s vs disk-cold \
         {cold_unpark:.2}s ({}x)\nboot ladder (2 devices): disk cold \
         boot {:.2}s vs dram-warm boot {:.2}s\nprefetch pipeline (64 \
         experts): sequential {:.2}s, overlapped {:.2}s, dram-warm h2d \
         only {:.2}s\nseed {seed} — all cells conserve tier residency \
         bytes (journal vs allocator) and serve the full trace. Replay \
         with `repro exp tier --seed {seed}`.\n",
        (cold_unpark / warm_unpark).round(),
        cold_b.total(),
        warm_b.total(),
        sequential_stage_time(&units, &t),
        pipelined_promote_time(&units, &t),
        warm_promote_time(&units, &t),
    ));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// ISSUE acceptance 1: DRAM-warm unpark strictly faster than disk
    /// cold boot on the same config — by multiples, not noise.
    #[test]
    fn dram_warm_unpark_strictly_beats_disk_cold() {
        let warm = unpark_latency(true).unwrap();
        let cold = unpark_latency(false).unwrap();
        assert!(
            warm * 3.0 < cold,
            "warm {warm:.2}s vs cold {cold:.2}s"
        );
    }

    /// ISSUE acceptance 2 + 3: on the bursty trace, dram-warm park
    /// strictly beats always-on on device-seconds without losing SLO
    /// attainment, and every cell's trace passes the invariant catalog
    /// (including tier byte conservation).
    #[test]
    fn park_unpark_beats_always_on_without_losing_slo() {
        let always =
            run_cell(Strategy::AlwaysOn, true, DEFAULT_SEED).unwrap();
        let warm =
            run_cell(Strategy::DramWarm, true, DEFAULT_SEED).unwrap();
        for c in [&always, &warm] {
            assert!(c.violations.is_empty(), "{:?}", c.violations);
            assert_eq!(c.completed, c.arrived);
            assert_eq!(c.truncated, 0);
        }
        assert!(warm.parks >= 1, "gaps must park");
        assert!(warm.unparks >= 1, "bursts must wake the replica");
        assert!(
            warm.device_seconds < always.device_seconds,
            "warm {} vs always-on {}",
            warm.device_seconds,
            always.device_seconds
        );
        assert!(
            warm.attainment + 0.02 >= always.attainment,
            "warm {} vs always-on {}",
            warm.attainment,
            always.attainment
        );
        assert_eq!(always.parks, 0);
        assert_eq!(always.unparks, 0);
    }

    /// The disk-cold park policy saves HBM-hours too, but pays for it
    /// in SLO during wake-ups: its unparks are cold-boot-class.
    #[test]
    fn disk_cold_unparks_are_cold_boot_class() {
        let cold =
            run_cell(Strategy::DiskCold, true, DEFAULT_SEED).unwrap();
        let warm =
            run_cell(Strategy::DramWarm, true, DEFAULT_SEED).unwrap();
        assert!(cold.violations.is_empty(), "{:?}", cold.violations);
        assert_eq!(cold.completed, cold.arrived, "late, but all served");
        assert!(cold.unparks >= 1);
        assert!(
            cold.mean_unpark > warm.mean_unpark * 3.0,
            "cold {} vs warm {}",
            cold.mean_unpark,
            warm.mean_unpark
        );
        assert!(
            cold.attainment < warm.attainment,
            "cold wake-ups must cost SLO: {} vs {}",
            cold.attainment,
            warm.attainment
        );
    }
}
