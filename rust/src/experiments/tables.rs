//! Tables 1/3 (progressive ablation, scale-up and scale-down) and Table 2
//! (throughput before/during/after scaling).

use anyhow::Result;

use crate::config::model::dsv2_lite;
use crate::config::SloConfig;
use crate::coordinator::{ServingSim, Trigger};
use crate::device::Timings;
use crate::engine::CostModel;
use crate::hmm::control::HmmOptions;
use crate::imm::manager::ImmOptions;
use crate::util::table::{f, Table};
use crate::workload::{WorkloadGen, WorkloadSpec};

use super::common::{elastic_with_opts, par};
use crate::scaling::ScalingMethod;

/// The cumulative ablation ladder of Tables 1/3.
fn ablation_ladder() -> Vec<(&'static str, HmmOptions, ImmOptions)> {
    let full = HmmOptions::default();
    let imm = ImmOptions::default();
    vec![
        ("ElasticMoE (full)", full, imm),
        (
            "- IPCAlloc",
            HmmOptions {
                ipc_safe_alloc: false,
                ..full
            },
            imm,
        ),
        (
            "- HCCL",
            HmmOptions {
                ipc_safe_alloc: false,
                use_p2p: false,
                ..full
            },
            imm,
        ),
        (
            "- PreInit",
            HmmOptions {
                ipc_safe_alloc: false,
                use_p2p: false,
                ..full
            },
            ImmOptions {
                pre_init: false,
                ..imm
            },
        ),
        (
            "- ZeroCopy",
            HmmOptions {
                ipc_safe_alloc: false,
                use_p2p: false,
                use_zero_copy: false,
                ..full
            },
            ImmOptions {
                pre_init: false,
                ..imm
            },
        ),
    ]
}

fn ablation(
    title: &str,
    from_n: usize,
    to_n: usize,
    expect: &str,
) -> Result<String> {
    let m = dsv2_lite();
    let mut table = Table::new(title).header([
        "Configuration",
        "Scale Time (s)",
        "Down Time (s)",
        "Peak Mem. (GB)",
    ]);
    for (name, hmm_opts, imm_opts) in ablation_ladder() {
        let mut meth = elastic_with_opts(
            &m,
            from_n.max(to_n),
            hmm_opts,
            imm_opts,
        );
        meth.boot(&par(&m, from_n)?)?;
        let out = meth.scale(&par(&m, to_n)?)?;
        table.row([
            name.to_string(),
            f(out.ready_after, 2),
            f(out.metrics.downtime, 2),
            f(out.metrics.peak_gb(), 1),
        ]);
    }
    let mut s = table.render();
    s.push_str(expect);
    Ok(s)
}

/// Table 1: scale-up DP3 -> DP4 (6 -> 8 devices at TP2).
pub fn table1() -> Result<String> {
    ablation(
        "Table 1: progressive ablation, scale-up DP3→DP4 (dsv2lite)",
        6,
        8,
        "\nExpected shape (paper: 2.43 / 3.14 / 10.42 / 62.78 / 67.40 s): \
         each removal slows scaling — IPCAlloc slightly (but raises peak \
         memory), HCCL by an order of magnitude, PreInit past 60 s; only \
         -ZeroCopy introduces downtime (= full scale time).\n",
    )
}

/// Table 3: scale-down DP4 -> DP3 (8 -> 6 devices at TP2).
pub fn table3() -> Result<String> {
    ablation(
        "Table 3: progressive ablation, scale-down DP4→DP3 (dsv2lite)",
        8,
        6,
        "\nExpected shape (paper: 1.38 / 1.36 / 7.74 / 50.21 / 64.57 s): \
         mirrors Table 1 with smaller absolute times (fewer transfers on \
         the way down); downtime only at -ZeroCopy.\n",
    )
}

/// Table 2: offline throughput before/during/after a 6->8 scale-up.
pub fn table2(opts: &super::common::ExpOptions) -> Result<String> {
    let fast = opts.fast;
    let m = dsv2_lite();
    // Enough work that the batch outlasts the slowest transition's
    // "during" window (~85 s for cold restart). The paper uses 10000.
    let n_requests = if fast { 4000 } else { 10000 };
    let command_at = 10.0;
    let methods: [&str; 3] = ["colocated", "cold", "elastic"];
    let mut results: Vec<(String, f64, f64, f64)> = Vec::new();

    // The "during" window is +-5s around the longest transition (the
    // paper pins it to Cold Restart's).
    let mut longest = 0.0f64;
    let mut raw: Vec<(String, crate::coordinator::SimOutput)> = Vec::new();
    for name in methods {
        let mut meth = super::common::make_method(name, &m, 8)?;
        let sim = ServingSim::new(
            CostModel::new(m.clone(), Timings::cloudmatrix()),
            SloConfig::new(1e9, 1e9), // offline: no SLO
        );
        let mut g = WorkloadGen::new(WorkloadSpec::offline_batch());
        let arrivals = g.offline_batch(n_requests);
        let out = sim.run(
            meth.as_mut(),
            &par(&m, 6)?,
            arrivals,
            Trigger::Manual(vec![(command_at, par(&m, 8)?)]),
            1e7, // offline: run to completion
        )?;
        if let Some(ev) = out.scaling_events.first() {
            longest = longest.max(ev.ready_after);
        }
        raw.push((super::common::display_name(name).to_string(), out));
    }
    let during0 = command_at - 5.0;
    let during1 = command_at + longest + 5.0;
    let slo = SloConfig::new(1e9, 1e9);
    for (name, out) in raw {
        let before = out.recorder.window(0.0, during0, &slo);
        let during = out.recorder.window(during0, during1, &slo);
        let after = out.recorder.window(during1, out.end_time, &slo);
        results.push((
            name,
            before.throughput_rps,
            during.throughput_rps,
            after.throughput_rps,
        ));
    }

    let mut table = Table::new(
        "Table 2: throughput (req/s) before/during/after scale-up 6→8 — \
         dsv2lite offline batch",
    )
    .header(["Method", "Before", "During", "After"]);
    for (name, b, d, a) in &results {
        table.row([name.clone(), f(*b, 3), f(*d, 3), f(*a, 3)]);
    }
    let mut s = table.render();
    s.push_str(
        "\nExpected shape (paper: Concurrent 1.34/0.47/2.27, Cold \
         6.00/2.06/7.82, Elastic 6.00/3.94/7.82): Colocated is crippled \
         even before scaling (reserved KV); during the transition Elastic \
         sustains ~2x Cold Restart's throughput with zero downtime; all \
         methods improve after.\n",
    );
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_ordering_matches_paper() {
        let m = dsv2_lite();
        let mut times = Vec::new();
        let mut downs = Vec::new();
        let mut peaks = Vec::new();
        for (_, h, i) in ablation_ladder() {
            let mut meth = elastic_with_opts(&m, 8, h, i);
            meth.boot(&par(&m, 6).unwrap()).unwrap();
            let out = meth.scale(&par(&m, 8).unwrap()).unwrap();
            times.push(out.ready_after);
            downs.push(out.metrics.downtime);
            peaks.push(out.metrics.peak_gb());
        }
        // Monotone non-decreasing scale time down the ladder.
        for w in times.windows(2) {
            assert!(w[1] >= w[0] * 0.99, "{times:?}");
        }
        // -HCCL is an order of magnitude over full.
        assert!(times[2] > times[0] * 2.5, "{times:?}");
        // -PreInit exceeds 40 s.
        assert!(times[3] > 40.0, "{times:?}");
        // Downtime appears only at -ZeroCopy.
        assert!(downs[..4].iter().all(|&d| d == 0.0), "{downs:?}");
        assert!(downs[4] > 0.0, "{downs:?}");
        // -IPCAlloc raises peak memory.
        assert!(peaks[1] > peaks[0] * 1.05, "{peaks:?}");
    }

    #[test]
    fn table2_fast_shape() {
        let report =
            table2(&super::common::ExpOptions::fast(true)).unwrap();
        assert!(report.contains("Before"));
        // Parse the elastic and cold rows and compare the During columns.
        let get = |name: &str| -> Vec<f64> {
            report
                .lines()
                .find(|l| l.contains(name))
                .unwrap()
                .split_whitespace()
                .rev()
                .take(3)
                .map(|x| x.parse::<f64>().unwrap())
                .collect::<Vec<_>>()
                .into_iter()
                .rev()
                .collect()
        };
        let elastic = get("ElasticMoE");
        let cold = get("Cold Restart");
        assert!(
            elastic[1] > cold[1],
            "during: elastic {elastic:?} vs cold {cold:?}"
        );
    }
}
