//! Prefill/decode disaggregation A/B (the pool-role tentpole): the
//! same mixed long-prompt/long-generation trace served by a unified
//! fleet and by a prefill/decode disaggregated fleet of identical size,
//! plus a fault cell that severs a KV handoff leg mid-copy.
//!
//! Both fleets are pinned at four 2-device replicas with no scaling
//! headroom, so the comparison isolates pool topology at equal
//! device-seconds. The mixed trace interleaves a long-generation tenant
//! ("gen": 4k prompts, 400-560 decode steps) with a long-prompt,
//! TTFT-sensitive tenant ("doc": 8k prompts, short answers). On the
//! unified fleet every replica's batch slots silt up with long-lived
//! decoders, so fresh prompts stall in admission behind them and TTFT
//! p99 inflates by seconds. The disaggregated fleet extracts each
//! sequence from its prefill replica the moment prefill completes and
//! hands its KV to the decode pool over a planned fabric leg
//! ([`crate::kvmigrate::plan_kv_migration`]), so prefill slots never
//! silt and admission is immediate.
//!
//! Acceptance, machine-checked per run: the disaggregated fleet
//! *strictly* beats unified on TTFT p99 at device-seconds within 10%;
//! the happy-path cell hands off every sequence with **zero** recompute
//! tokens; the `KvCopyFail` cell falls back to recompute-on-decode
//! without losing a request; and every cell passes the full invariant
//! catalog ([`crate::chaos::check_all`]) including block conservation
//! and exactly-once handoff disposition over the new legs. See
//! `docs/architecture/10-disaggregation.md`.

use std::cell::RefCell;
use std::rc::Rc;

use anyhow::{bail, Result};

use crate::chaos::{
    check_all, FaultInjector, FaultKind, FaultPlan, TraceEvent,
    Violation,
};
use crate::config::model::dsv2_lite;
use crate::config::SloConfig;
use crate::coordinator::{
    FleetLimits, FleetOutput, FleetPolicy, FleetSim, PolicyMode,
    PoolRole, Router,
};
use crate::device::Timings;
use crate::engine::CostModel;
use crate::hmm::control::HmmOptions;
use crate::imm::manager::ImmOptions;
use crate::scaling::ScalingMethod;
use crate::util::table::Table;
use crate::workload::{
    MultiTenantGen, RateProfile, Request, TenantSpec, WorkloadSpec,
};

use super::common::elastic_with_opts;

/// Default seed when `--seed` is not given.
pub const DEFAULT_SEED: u64 = 23;

/// Fleet shape shared by every cell: replica count and devices each.
const REPLICAS: usize = 4;
const DEVICES_PER_REPLICA: usize = 2;

/// Per-replica concurrent-sequence cap. Small enough that the unified
/// baseline's slots saturate with long-lived decoders under the mixed
/// trace (the contention the paper's disaggregation removes), while the
/// decode pool adopts past it and stays weight-read-bound.
const MAX_BATCH: usize = 16;

/// No headroom in any direction: the pool is exactly the boot
/// footprint, vertical max equals base, and `min_replicas` pins the
/// count — both cells hold the same devices for the whole run.
fn limits() -> FleetLimits {
    FleetLimits {
        pool_devices: REPLICAS * DEVICES_PER_REPLICA,
        replica_base: DEVICES_PER_REPLICA,
        replica_max: DEVICES_PER_REPLICA,
        step: DEVICES_PER_REPLICA,
        min_replicas: REPLICAS,
    }
}

fn policy() -> FleetPolicy {
    let mut p = FleetPolicy::new(
        PolicyMode::Hybrid,
        limits(),
        SloConfig::scale_up_demo(),
    );
    // Capacity is pinned by `limits()`; infinite patience keeps the
    // estimator from even proposing actions, so the A/B never pays a
    // switchover window.
    p.estimator.up_patience = u32::MAX;
    p.estimator.down_patience = u32::MAX;
    p
}

fn elastic_factory(
) -> impl FnMut(usize) -> Result<Box<dyn ScalingMethod>> {
    move |_| {
        Ok(Box::new(elastic_with_opts(
            &dsv2_lite(),
            DEVICES_PER_REPLICA,
            HmmOptions::default(),
            ImmOptions::default(),
        )) as Box<dyn ScalingMethod>)
    }
}

fn horizon(fast: bool) -> f64 {
    if fast {
        120.0
    } else {
        180.0
    }
}

/// The mixed trace both fleets serve: tenant 0 ("gen") holds batch
/// slots for hundreds of decode steps per request; tenant 1 ("doc")
/// sends the long prompts whose TTFT the contention punishes. Both
/// prompt lengths sit above the copy/recompute break-even, so every
/// happy-path handoff plans as a fabric copy.
fn workload(seed: u64, fast: bool) -> Vec<Request> {
    let slo = SloConfig::scale_up_demo();
    MultiTenantGen::new(vec![
        TenantSpec::new(
            "gen",
            WorkloadSpec {
                prompt_len: 4096,
                decode_min: 400,
                decode_max: 560,
                profile: RateProfile::Fixed(7.0),
                seed,
            },
            slo,
        ),
        TenantSpec::new(
            "doc",
            WorkloadSpec {
                prompt_len: 8192,
                decode_min: 16,
                decode_max: 32,
                profile: RateProfile::Fixed(2.0),
                seed: seed ^ 0x9e37_79b9,
            },
            slo,
        ),
    ])
    .arrivals_until(horizon(fast))
}

/// Boot roles per cell. An empty vec is the unified control (every
/// replica defaults to [`PoolRole::Unified`]).
fn roles(cell: &str) -> Vec<PoolRole> {
    match cell {
        "unified" => Vec::new(),
        _ => vec![
            PoolRole::Prefill,
            PoolRole::Decode,
            PoolRole::Prefill,
            PoolRole::Decode,
        ],
    }
}

/// The kvfail cell severs the very first handoff's fabric copy one leg
/// in (capacity is pinned, so handoffs are the only injector events):
/// the plan must abort cleanly and the sequence re-prefill on its
/// decode replica instead of being lost.
fn fault_plan(cell: &str) -> FaultPlan {
    match cell {
        "disagg-kvfail" => FaultPlan::single(
            0,
            FaultKind::KvCopyFail { after_legs: 1 },
        ),
        _ => FaultPlan::none(),
    }
}

/// One cell's measurements.
struct CellResult {
    cell: &'static str,
    arrived: usize,
    completed: usize,
    ttft_p99: f64,
    device_seconds: f64,
    handoffs: usize,
    adopted: usize,
    recomputed: usize,
    recompute_tokens: u64,
    fault_fired: bool,
    violations: Vec<Violation>,
    state_hash: u64,
    telemetry: Option<crate::obs::Telemetry>,
}

fn count(out: &FleetOutput, pred: impl Fn(&TraceEvent) -> bool) -> usize {
    out.trace.events.iter().filter(|e| pred(e)).count()
}

/// Run one cell on the seeded mixed trace.
fn run_cell(
    cell: &'static str,
    seed: u64,
    fast: bool,
) -> Result<CellResult> {
    run_cell_obs(cell, seed, fast, false)
}

/// [`run_cell`] with the telemetry registry optionally enabled (exports
/// the `handoffs_planned`/`handoff_bytes`/`handoff_adoptions` counters
/// alongside the standard fleet series).
fn run_cell_obs(
    cell: &'static str,
    seed: u64,
    fast: bool,
    obs: bool,
) -> Result<CellResult> {
    let (out, arrived) = run_cell_raw(cell, seed, fast, obs)?;
    let violations = check_all(&out.trace);
    Ok(CellResult {
        cell,
        arrived,
        completed: out.recorder.count(),
        ttft_p99: out.recorder.ttft_percentile_by_arrival(
            0.0,
            f64::INFINITY,
            99.0,
        ),
        device_seconds: out.device_seconds(),
        handoffs: count(&out, |e| {
            matches!(e, TraceEvent::HandoffPlanned { .. })
        }),
        adopted: out.pool_handoff.copied,
        recomputed: out.pool_handoff.recomputed,
        recompute_tokens: out.pool_handoff.recompute_tokens,
        fault_fired: count(&out, |e| {
            matches!(e, TraceEvent::FaultFired { .. })
        }) > 0,
        violations,
        state_hash: out.state_hash,
        telemetry: out.telemetry,
    })
}

/// The SLO every disagg cell is judged against (shared with
/// [`crate::report`]).
pub fn report_slo() -> SloConfig {
    SloConfig::scale_up_demo()
}

/// One fully-instrumented disagg cell for `repro report`: the complete
/// [`FleetOutput`] plus the invariant verdict.
pub struct ReportCell {
    pub name: String,
    pub arrived: usize,
    pub out: FleetOutput,
    pub violations: Vec<Violation>,
}

/// Run the pool matrix with full instrumentation for `repro report`.
pub fn report_cells(seed: u64, fast: bool) -> Result<Vec<ReportCell>> {
    let mut cells = Vec::new();
    for cell in matrix() {
        let (out, arrived) = run_cell_raw(cell, seed, fast, true)?;
        let violations = check_all(&out.trace);
        cells.push(ReportCell {
            name: cell.to_string(),
            arrived,
            out,
            violations,
        });
    }
    Ok(cells)
}

/// Run one cell and hand back the complete [`FleetOutput`] instead of
/// the summarized [`CellResult`].
fn run_cell_raw(
    cell: &'static str,
    seed: u64,
    fast: bool,
    obs: bool,
) -> Result<(FleetOutput, usize)> {
    let mut sim = FleetSim::new(
        CostModel::new(dsv2_lite(), Timings::cloudmatrix()),
        SloConfig::scale_up_demo(),
        Router::JoinShortestQueue,
    );
    sim.obs = obs;
    sim.max_batch = MAX_BATCH;
    // Short routing/handoff window: staged sequences wait at most half
    // a second between finishing prefill and having their KV leg
    // planned.
    sim.window = 0.5;
    sim.initial_roles = roles(cell);
    sim.injector = Some(Rc::new(RefCell::new(FaultInjector::new(
        fault_plan(cell),
    ))));
    let mut policy = policy();
    let arrivals = workload(seed, fast);
    let arrived = arrivals.len();
    let out = sim.run(
        &mut policy,
        &mut elastic_factory(),
        REPLICAS,
        arrivals,
        horizon(fast),
    )?;
    Ok((out, arrived))
}

/// One cell of [`conformance`]: the fields the determinism sweep
/// (`rust/tests/determinism.rs`) compares across seeds and re-runs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConformanceCell {
    pub cell: &'static str,
    pub arrived: usize,
    pub completed: usize,
    /// `HandoffPlanned` legs across the run.
    pub handoffs: usize,
    /// Sequences adopted with their KV intact on a decode replica.
    pub adopted: usize,
    /// Sequences that fell back to recompute-on-decode.
    pub recomputed: usize,
    /// Invariant violations found by [`check_all`] (must be zero).
    pub violations: usize,
    /// The run's [`FleetOutput::state_hash`] — equal across same-seed
    /// re-runs.
    pub state_hash: u64,
}

/// Run the pool matrix for one seed and return every cell's conformance
/// summary plus its run digest. Entry point for the seed-sweep
/// determinism suite.
pub fn conformance(seed: u64) -> Result<Vec<ConformanceCell>> {
    conformance_with_obs(seed, false)
}

/// [`conformance`] with the telemetry registry on or off: the
/// determinism suite runs each cell both ways and asserts the digests
/// are bit-identical (telemetry must be a pure observer).
pub fn conformance_with_obs(
    seed: u64,
    obs: bool,
) -> Result<Vec<ConformanceCell>> {
    let mut cells = Vec::new();
    for cell in matrix() {
        let r = run_cell_obs(cell, seed, true, obs)?;
        cells.push(ConformanceCell {
            cell: r.cell,
            arrived: r.arrived,
            completed: r.completed,
            handoffs: r.handoffs,
            adopted: r.adopted,
            recomputed: r.recomputed,
            violations: r.violations.len(),
            state_hash: r.state_hash,
        });
    }
    Ok(cells)
}

/// The pool matrix: unified control, disaggregated happy path, and the
/// severed-handoff-leg fault cell, all on the identical trace.
fn matrix() -> [&'static str; 3] {
    ["unified", "disagg", "disagg-kvfail"]
}

/// Per-cell acceptance: zero invariant violations, everything served
/// exactly once, and the cell's handoff tally matches its topology.
fn assert_cell(r: &CellResult, seed: u64) -> Result<()> {
    if !r.violations.is_empty() {
        bail!(
            "cell [{}] violated {} invariant(s) (replay with \
             `repro exp disagg --seed {seed}`): {}",
            r.cell,
            r.violations.len(),
            r.violations
                .iter()
                .map(|v| v.to_string())
                .collect::<Vec<_>>()
                .join("; ")
        );
    }
    if r.completed != r.arrived {
        bail!(
            "cell [{}]: {} of {} requests completed (seed {seed})",
            r.cell,
            r.completed,
            r.arrived
        );
    }
    match r.cell {
        "unified" => {
            if r.handoffs != 0 || r.adopted != 0 || r.recomputed != 0 {
                bail!(
                    "cell [unified] must not hand off: planned {}, \
                     adopted {}, recomputed {} (seed {seed})",
                    r.handoffs,
                    r.adopted,
                    r.recomputed
                );
            }
        }
        "disagg" => {
            // The zero-recompute happy path: every sequence's KV
            // crossed the fabric and was adopted mid-stream.
            if r.recomputed != 0 || r.recompute_tokens != 0 {
                bail!(
                    "cell [disagg]: happy path recomputed {} seqs / \
                     {} tokens (seed {seed})",
                    r.recomputed,
                    r.recompute_tokens
                );
            }
            if r.adopted != r.arrived {
                bail!(
                    "cell [disagg]: {} of {} sequences adopted by the \
                     decode pool (seed {seed})",
                    r.adopted,
                    r.arrived
                );
            }
        }
        "disagg-kvfail" => {
            if !r.fault_fired {
                bail!(
                    "cell [disagg-kvfail]: fault never fired (seed \
                     {seed})"
                );
            }
            if r.recomputed == 0 {
                bail!(
                    "cell [disagg-kvfail]: severed leg must surface \
                     as recompute-on-decode (seed {seed})"
                );
            }
            if r.adopted + r.recomputed != r.arrived {
                bail!(
                    "cell [disagg-kvfail]: {} adopted + {} recomputed \
                     != {} arrived (seed {seed})",
                    r.adopted,
                    r.recomputed,
                    r.arrived
                );
            }
        }
        other => bail!("unknown cell '{other}'"),
    }
    Ok(())
}

/// Cross-cell acceptance: the headline claim. Disaggregation must
/// *strictly* beat the unified control on TTFT p99 while holding the
/// same device-seconds (within 10% — the pinned fleets differ only in
/// drain-tail length).
fn assert_headline(
    unified: &CellResult,
    disagg: &CellResult,
    seed: u64,
) -> Result<()> {
    if !(disagg.ttft_p99 < unified.ttft_p99) {
        bail!(
            "disagg TTFT p99 {:.3}s must strictly beat unified {:.3}s \
             (seed {seed})",
            disagg.ttft_p99,
            unified.ttft_p99
        );
    }
    let drift = (disagg.device_seconds - unified.device_seconds).abs()
        / unified.device_seconds;
    if drift > 0.10 {
        bail!(
            "device-seconds diverged {:.1}% (unified {:.0}, disagg \
             {:.0}, seed {seed}) — not an equal-budget comparison",
            drift * 100.0,
            unified.device_seconds,
            disagg.device_seconds
        );
    }
    Ok(())
}

/// `repro exp disagg [--fast] [--seed N]`.
pub fn run(opts: &super::common::ExpOptions) -> Result<String> {
    let fast = opts.fast;
    let seed = opts.seed_or(DEFAULT_SEED);
    let mut results = Vec::new();
    for cell in matrix() {
        let obs = cell == "disagg" && opts.wants_obs();
        let r = run_cell_obs(cell, seed, fast, obs)?;
        if obs {
            opts.export_telemetry(r.telemetry.as_ref())?;
        }
        assert_cell(&r, seed)?;
        results.push(r);
    }
    let unified = &results[0];
    let disagg = &results[1];
    assert_headline(unified, disagg, seed)?;

    let mut table = Table::new(
        "Prefill/decode disaggregation vs unified pools: one mixed \
         long-prompt/long-generation trace, equal device-seconds",
    )
    .header([
        "cell",
        "done",
        "ttft p99 (s)",
        "device-s",
        "handoffs",
        "adopted",
        "recomputed",
        "violations",
    ]);
    for r in &results {
        table.row([
            r.cell.to_string(),
            format!("{}/{}", r.completed, r.arrived),
            format!("{:.3}", r.ttft_p99),
            format!("{:.0}", r.device_seconds),
            r.handoffs.to_string(),
            r.adopted.to_string(),
            r.recomputed.to_string(),
            r.violations.len().to_string(),
        ]);
    }
    let mut out = table.render();
    out.push_str(&format!(
        "\nseed {seed} — disaggregation cut TTFT p99 {:.1}x (unified \
         {:.3}s -> {:.3}s) at device-seconds within {:.1}%, with zero \
         recompute tokens on the happy path; the severed-leg cell \
         recomputed {} sequence(s) on its decode replica and still \
         served its full trace. Replay with `repro exp disagg --seed \
         {seed}`.\n",
        unified.ttft_p99 / disagg.ttft_p99,
        unified.ttft_p99,
        disagg.ttft_p99,
        (disagg.device_seconds - unified.device_seconds).abs()
            / unified.device_seconds
            * 100.0,
        results[2].recomputed,
    ));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// ISSUE acceptance: disaggregation strictly beats unified on TTFT
    /// p99 at equal device-seconds, the happy path hands off with zero
    /// recompute tokens, the severed-leg cell falls back to
    /// recompute-on-decode, and every cell passes the invariant
    /// catalog.
    #[test]
    fn disagg_beats_unified_and_survives_kv_copy_fail() {
        let unified = run_cell("unified", DEFAULT_SEED, true).unwrap();
        let disagg = run_cell("disagg", DEFAULT_SEED, true).unwrap();
        let kvfail =
            run_cell("disagg-kvfail", DEFAULT_SEED, true).unwrap();
        assert_cell(&unified, DEFAULT_SEED).unwrap();
        assert_cell(&disagg, DEFAULT_SEED).unwrap();
        assert_cell(&kvfail, DEFAULT_SEED).unwrap();
        assert_headline(&unified, &disagg, DEFAULT_SEED).unwrap();
    }

    /// The conformance summary is bit-reproducible across re-runs of
    /// the same seed (the determinism suite sweeps more seeds).
    #[test]
    fn conformance_is_reproducible() {
        let a = conformance(DEFAULT_SEED).unwrap();
        for cell in &a {
            assert_eq!(cell.violations, 0, "{cell:?}");
            assert_eq!(cell.completed, cell.arrived, "{cell:?}");
        }
        let b = conformance(DEFAULT_SEED).unwrap();
        assert_eq!(a, b, "conformance summary must be reproducible");
    }
}
