//! Experiment harness: one module per table/figure of the paper's
//! evaluation (§7 + Appendix A). Each regenerates the corresponding rows;
//! `repro exp <id>` prints them and writes `reports/<id>.txt`.
//!
//! | id      | paper artifact                                   |
//! |---------|--------------------------------------------------|
//! | fig1a/b | throughput vs devices / devices vs goodput       |
//! | fig4a/b | init-latency breakdown / weight memory vs EP     |
//! | fig7    | scale-up latency, 5 methods x 3 models           |
//! | fig8    | scale-up peak memory (DSv2-Lite)                 |
//! | fig9a/b | SLO dynamics, scale-up / scale-down              |
//! | fig10   | SLO% vs RPS sweep                                |
//! | fig11   | ElasticMoE scale-up latency breakdown            |
//! | fig12   | scale-down latency, methods x models             |
//! | table1  | progressive ablation, scale-up DP3->DP4          |
//! | table2  | throughput before/during/after scaling           |
//! | table3  | progressive ablation, scale-down DP4->DP3        |
//! | fleet   | fleet scenarios (beyond the paper): hybrid       |
//! |         | vertical×horizontal autoscaling, diurnal,        |
//! |         | flash-crowd and multi-tenant traffic             |
//! | placement | expert placement (beyond the paper): round-    |
//! |         | robin vs load-aware vs replication on a          |
//! |         | Zipf-skewed routing trace across an EP change    |
//! | kvmigrate | live-sequence KV handoff (§4.4 claim): remap / |
//! |         | p2p-copy / recompute vs drain-and-recompute      |
//! |         | across DP4→DP6 and DP4→DP3 under long contexts   |
//! | chaos   | fault-injection conformance: method × direction  |
//! |         | × fault matrix with machine-checked trace        |
//! |         | invariants and clean abort/rollback              |
//! | tier    | tiered weight store (beyond the paper): DRAM-    |
//! |         | warm park/unpark vs disk-cold vs always-on on a  |
//! |         | serverless on/off bursty trace, with the tier    |
//! |         | byte-conservation invariant checked               |
//! | reconcile | control-plane reconciler conformance: the      |
//! |         | heartbeat-loss / stale-snapshot / duplicate-     |
//! |         | command fault matrix with the bounded-convergence|
//! |         | invariant checked per cell                       |
//! | disagg  | prefill/decode disaggregation (beyond the paper):|
//! |         | unified vs pool-typed fleets on one mixed trace  |
//! |         | at equal device-seconds, with KV handoff legs    |
//! |         | planned per sequence and a severed-leg fault cell|

pub mod chaos;
pub mod common;
pub mod disagg;
pub mod fig1;
pub mod fleet;
pub mod kvmigrate;
pub mod fig4;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod fig10;
pub mod fig11;
pub mod fig12;
pub mod placement;
pub mod reconcile;
pub mod tables;
pub mod tier;

use anyhow::{bail, Result};

pub use common::ExpOptions;

/// All experiment ids in paper order.
pub const ALL: &[&str] = &[
    "fig1a", "fig1b", "fig4a", "fig4b", "fig7", "fig8", "fig9a", "fig9b",
    "fig10", "fig11", "fig12", "table1", "table2", "table3", "fleet",
    "placement", "kvmigrate", "chaos", "tier", "reconcile", "disagg",
];

/// Run one experiment by id, returning the rendered report.
pub fn run(id: &str, fast: bool) -> Result<String> {
    run_with(id, &ExpOptions::fast(fast))
}

/// Like [`run`], with an explicit workload/fault seed (`repro exp
/// --seed N`); see [`ExpOptions`].
pub fn run_seeded(id: &str, fast: bool, seed: Option<u64>) -> Result<String> {
    run_with(
        id,
        &ExpOptions {
            fast,
            seed,
            ..Default::default()
        },
    )
}

/// Run one experiment by id under shared [`ExpOptions`] — the single
/// dispatch point: flag parsing happens once in
/// [`ExpOptions::from_args`], and every experiment consumes the same
/// struct instead of re-declaring its own `fast`/`seed` plumbing.
pub fn run_with(id: &str, opts: &ExpOptions) -> Result<String> {
    let report = match id {
        "fig1a" => fig1::fig1a()?,
        "fig1b" => fig1::fig1b()?,
        "fig4a" => fig4::fig4a()?,
        "fig4b" => fig4::fig4b()?,
        "fig7" => fig7::run(opts)?,
        "fig8" => fig8::run()?,
        "fig9a" => fig9::scale_up(opts)?,
        "fig9b" => fig9::scale_down(opts)?,
        "fig10" => fig10::run(opts)?,
        "fig11" => fig11::run()?,
        "fig12" => fig12::run(opts)?,
        "table1" => tables::table1()?,
        "table2" => tables::table2(opts)?,
        "table3" => tables::table3()?,
        "fleet" => fleet::run(opts)?,
        "placement" => placement::run(opts)?,
        "kvmigrate" => kvmigrate::run(opts)?,
        "chaos" => chaos::run(opts)?,
        "tier" => tier::run(opts)?,
        "reconcile" => reconcile::run(opts)?,
        "disagg" => disagg::run(opts)?,
        other => bail!("unknown experiment '{other}' (see `repro exp list`)"),
    };
    // Persist alongside printing.
    let _ = std::fs::create_dir_all("reports");
    let _ = std::fs::write(format!("reports/{id}.txt"), &report);
    Ok(report)
}
