//! Fig 9: SLO dynamics over time around a scaling event (DSv2-Lite). At
//! t=0 the load shifts so the current configuration becomes unsustainable;
//! the scale command fires at t=30 s for every method.

use anyhow::Result;

use crate::config::model::dsv2_lite;
use crate::config::{ParallelConfig, SloConfig};
use crate::coordinator::{ServingSim, Trigger};
use crate::device::Timings;
use crate::engine::CostModel;
use crate::util::table::{f, Table};
use crate::workload::{RateProfile, WorkloadGen, WorkloadSpec};

use super::common::{display_name, make_method, par, KV_BYTES};

const COMMAND_AT: f64 = 30.0;
const HORIZON: f64 = 240.0;
const BUCKET: f64 = 20.0;

fn cost() -> CostModel {
    CostModel::new(dsv2_lite(), Timings::cloudmatrix())
}

fn capacity(n: usize) -> f64 {
    let m = dsv2_lite();
    let p = ParallelConfig::standard(n / m.tp, m.tp, (0..n).collect())
        .unwrap();
    cost().steady_throughput_rps(&p, 64 << 30, 2000, 125)
}

fn workload(profile: RateProfile) -> Vec<crate::workload::Request> {
    let mut g = WorkloadGen::new(WorkloadSpec {
        prompt_len: 2000,
        decode_min: 100,
        decode_max: 150,
        profile,
        seed: 17,
    });
    g.arrivals_until(HORIZON)
}

fn timeline_row(
    method: &str,
    from_n: usize,
    to_n: usize,
    profile: RateProfile,
    slo: SloConfig,
    per_npu: bool,
) -> Result<Vec<f64>> {
    let m = dsv2_lite();
    let cluster_n = from_n.max(to_n);
    let mut meth = make_method(method, &m, cluster_n)?;
    let sim = ServingSim::new(cost(), slo);
    let out = sim.run(
        meth.as_mut(),
        &par(&m, from_n)?,
        workload(profile),
        Trigger::Manual(vec![(COMMAND_AT, par(&m, to_n)?)]),
        HORIZON,
    )?;
    let mut row = Vec::new();
    let mut t = 0.0;
    while t < HORIZON {
        let mut v = out
            .recorder
            .attainment_by_arrival(t, t + BUCKET, &slo);
        if per_npu {
            // Devices active during this bucket (last timeline entry <= t).
            let devs = out
                .device_timeline
                .iter()
                .rev()
                .find(|(at, _)| *at <= t)
                .map(|(_, n)| *n)
                .unwrap_or(from_n) as f64;
            v /= devs;
        }
        row.push(v);
        t += BUCKET;
    }
    let _ = KV_BYTES;
    Ok(row)
}

fn render(
    title: &str,
    rows: Vec<(String, Vec<f64>)>,
    note: &str,
) -> String {
    let n_buckets = rows.first().map(|(_, r)| r.len()).unwrap_or(0);
    let mut table = Table::new(title).header(
        std::iter::once("method".to_string()).chain(
            (0..n_buckets)
                .map(|i| format!("t={:.0}", i as f64 * BUCKET)),
        ),
    );
    for (name, row) in rows {
        table.row(
            std::iter::once(name).chain(row.iter().map(|v| {
                if v.is_nan() {
                    "-".to_string()
                } else {
                    f(*v, 2)
                }
            })),
        );
    }
    let mut out = table.render();
    out.push_str(note);
    out
}

/// Fig 9a: scale-up 4->6 under rising load (TTFT<=5s, TPOT<=1.5s).
pub fn scale_up(opts: &super::common::ExpOptions) -> Result<String> {
    let fast = opts.fast;
    let cap4 = capacity(4);
    // Load jumps at t=0 beyond what 4 devices sustain (but within what 6
    // devices can absorb).
    let profile = RateProfile::Step {
        before: cap4 * 0.55,
        after: cap4 * 1.2,
        at: 0.0,
    };
    let methods: &[&str] = if fast {
        &["elastic", "cold"]
    } else {
        &["elastic", "cold", "colocated"]
    };
    let slo = SloConfig::scale_up_demo();
    let mut rows = Vec::new();
    for &name in methods {
        rows.push((
            display_name(name).to_string(),
            timeline_row(name, 4, 6, profile.clone(), slo, false)?,
        ));
    }
    Ok(render(
        "Fig 9a: SLO attainment timeline, scale-up 4→6 (command at t=30)",
        rows,
        "\nExpected shape: all methods dip as load rises; ElasticMoE \
         recovers within seconds of the command and holds ≥0.9; Cold \
         Restart stays degraded through its downtime; Colocated remains \
         unstable (memory-strangled during overlap).\n",
    ))
}

/// Fig 9b: scale-down 6->4 under reduced load; metric is SLO-per-NPU.
pub fn scale_down(opts: &super::common::ExpOptions) -> Result<String> {
    let fast = opts.fast;
    let cap4 = capacity(4);
    let profile = RateProfile::Step {
        before: cap4 * 0.8,
        after: cap4 * 0.3,
        at: 0.0,
    };
    let methods: &[&str] = if fast {
        &["elastic", "cold"]
    } else {
        &["elastic", "cold", "colocated"]
    };
    let slo = SloConfig::scale_down_demo();
    let mut rows = Vec::new();
    for &name in methods {
        rows.push((
            display_name(name).to_string(),
            timeline_row(name, 6, 4, profile.clone(), slo, true)?,
        ));
    }
    Ok(render(
        "Fig 9b: SLO-per-NPU timeline, scale-down 6→4 (command at t=30)",
        rows,
        "\nExpected shape: demand is low so every method eventually meets \
         SLO; ElasticMoE releases the two NPUs almost immediately, giving \
         the best normalized SLO-per-NPU after the command.\n",
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elastic_recovers_faster_than_cold_restart() {
        let cap4 = capacity(4);
        let profile = RateProfile::Step {
            before: cap4 * 0.55,
            after: cap4 * 1.2,
            at: 0.0,
        };
        let slo = SloConfig::scale_up_demo();
        let e =
            timeline_row("elastic", 4, 6, profile.clone(), slo, false)
                .unwrap();
        let c = timeline_row("cold", 4, 6, profile, slo, false).unwrap();
        // Bucket right after the command (t in [40, 60)): elastic should
        // attain more than cold restart.
        let idx = (50.0 / BUCKET) as usize;
        let (ev, cv) = (e[idx], c[idx]);
        assert!(
            ev > cv || (ev.is_nan() && cv.is_nan()),
            "post-command: elastic {ev} vs cold {cv} (rows {e:?} vs {c:?})"
        );
        // Late buckets: elastic sustains the target.
        let late = e[e.len() - 2];
        assert!(late > 0.85 || late.is_nan(), "late elastic {late}");
    }
}
