//! Fig 1: the headline motivation. (a) achievable throughput at a given
//! device count — ElasticMoE's single elastic instance (EP grows with the
//! fleet) vs horizontal replication of the minimal configuration (EP
//! frozen, experts replicated). (b) the dual: devices needed to reach a
//! goodput target.

use anyhow::Result;

use crate::config::model::dsv2_lite;
use crate::config::ParallelConfig;
use crate::device::Timings;
use crate::engine::CostModel;
use crate::util::table::{f, Table};

const HBM: u64 = 64 << 30;
const PROMPT: usize = 2000;
const DECODE: usize = 600;

fn elastic_rps(cost: &CostModel, n: usize) -> f64 {
    let m = &cost.model;
    let p = ParallelConfig::standard(n / m.tp, m.tp, (0..n).collect())
        .unwrap();
    cost.steady_throughput_rps(&p, HBM, PROMPT, DECODE)
}

fn horizontal_rps(cost: &CostModel, n: usize) -> f64 {
    // Replicas of the minimal config; experts confined per replica.
    let m = &cost.model;
    let base = m.min_devices.max(m.tp);
    let replicas = n / base;
    if replicas == 0 {
        return 0.0;
    }
    let p = ParallelConfig::with_ep(
        replicas * base / m.tp,
        m.tp,
        base, // EP stays at the minimal instance's degree
        (0..replicas * base).collect(),
    )
    .unwrap();
    cost.steady_throughput_rps(&p, HBM, PROMPT, DECODE)
}

pub fn fig1a() -> Result<String> {
    let cost = CostModel::new(dsv2_lite(), Timings::cloudmatrix());
    let mut table = Table::new(
        "Fig 1a: achievable throughput (RPS) vs devices — dsv2lite",
    )
    .header(["devices", "ElasticMoE (one elastic instance)", "Horizontal (replicas)"]);
    for n in [2usize, 4, 8, 16, 32] {
        table.row([
            n.to_string(),
            f(elastic_rps(&cost, n), 2),
            f(horizontal_rps(&cost, n), 2),
        ]);
    }
    let mut out = table.render();
    out.push_str(
        "\nExpected shape: ElasticMoE dominates at every fleet size — \
         growing EP shrinks per-device expert memory, freeing HBM for KV \
         and larger batches, while replicas duplicate experts.\n",
    );
    Ok(out)
}

pub fn fig1b() -> Result<String> {
    let cost = CostModel::new(dsv2_lite(), Timings::cloudmatrix());
    let mut table = Table::new(
        "Fig 1b: devices required for a goodput target — dsv2lite",
    )
    .header(["target RPS", "ElasticMoE", "Horizontal"]);
    for target in [2.0f64, 5.0, 10.0, 20.0, 40.0] {
        let need = |f: &dyn Fn(usize) -> f64| -> String {
            for n in 1..=96 {
                let m = dsv2_lite();
                if n % m.tp != 0 {
                    continue;
                }
                if f(n) >= target {
                    return n.to_string();
                }
            }
            ">96".into()
        };
        table.row([
            format!("{target}"),
            need(&|n| elastic_rps(&cost, n)),
            need(&|n| horizontal_rps(&cost, n)),
        ]);
    }
    let mut out = table.render();
    out.push_str(
        "\nExpected shape: ElasticMoE reaches each goodput level with \
         fewer accelerators (and in fine-grained increments; horizontal \
         only grows in whole-replica quanta).\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elastic_dominates_horizontal() {
        let cost = CostModel::new(dsv2_lite(), Timings::cloudmatrix());
        for n in [8usize, 16, 32] {
            let e = elastic_rps(&cost, n);
            let h = horizontal_rps(&cost, n);
            assert!(e > h, "{n} devices: elastic {e} vs horizontal {h}");
        }
    }

    #[test]
    fn both_grow_with_devices() {
        let cost = CostModel::new(dsv2_lite(), Timings::cloudmatrix());
        assert!(elastic_rps(&cost, 16) > elastic_rps(&cost, 4));
        assert!(horizontal_rps(&cost, 16) > horizontal_rps(&cost, 4));
    }

    #[test]
    fn reports_render() {
        assert!(fig1a().unwrap().contains("devices"));
        assert!(fig1b().unwrap().contains("target RPS"));
    }
}
