//! Fig 12 (Appendix A.2): scale-down latency across methods and models —
//! the mirror of Fig 7, with transitions reducing the NPU count.

use anyhow::Result;

use crate::util::table::{f, Table};

use super::common::{
    display_name, make_method, par, par_on, paper_models, METHODS,
};
use crate::config::ModelConfig;

fn down_transitions(m: &ModelConfig) -> Vec<(usize, usize)> {
    match m.name {
        "dsv3" => vec![(64, 48), (48, 40), (48, 32)],
        _ => vec![(10, 8), (8, 6), (6, 4), (4, 2)],
    }
    .into_iter()
    .filter(|&(_, b)| b >= m.min_devices && b % m.tp == 0)
    .collect()
}

pub fn run(opts: &super::common::ExpOptions) -> Result<String> {
    let fast = opts.fast;
    let mut out = String::new();
    let models = paper_models();
    let models = if fast { &models[..1] } else { &models[..] };
    for m in models {
        let mut table = Table::new(&format!(
            "Fig 12: scale-down latency (s) — {}",
            m.name
        ))
        .header(
            std::iter::once("transition".to_string()).chain(
                METHODS
                    .iter()
                    .filter(|s| **s != "horizontal")
                    .map(|s| display_name(s).to_string()),
            ),
        );
        for &(from_n, to_n) in &down_transitions(m) {
            let mut cells = vec![format!("{from_n}→{to_n}")];
            for &name in METHODS.iter().filter(|s| **s != "horizontal") {
                let cell = match down_latency(name, m, from_n, to_n) {
                    Ok(Some(t)) => f(t, 2),
                    _ => "—".to_string(),
                };
                cells.push(cell);
            }
            table.row(cells);
        }
        out.push_str(&table.render());
        out.push('\n');
    }
    out.push_str(
        "Expected shape: ElasticMoE completes scale-down in <0.15x the \
         fastest baseline (80-90% reduction; DSv3 48→32 ≈0.10x).\n",
    );
    Ok(out)
}

pub fn down_latency(
    method: &str,
    m: &ModelConfig,
    from_n: usize,
    to_n: usize,
) -> Result<Option<f64>> {
    match method {
        "extravagant" => {
            let mut meth = make_method(method, m, from_n + to_n)?;
            meth.boot(&par(m, from_n)?)?;
            let out = meth.scale(&par_on(m, from_n..from_n + to_n)?)?;
            Ok(Some(out.ready_after))
        }
        "horizontal" => Ok(None),
        _ => {
            let mut meth = make_method(method, m, from_n)?;
            meth.boot(&par(m, from_n)?)?;
            let out = meth.scale(&par(m, to_n)?)?;
            Ok(Some(out.ready_after))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::dsv2_lite;

    #[test]
    fn elastic_scale_down_is_fastest() {
        let m = dsv2_lite();
        let e = down_latency("elastic", &m, 6, 4).unwrap().unwrap();
        let c = down_latency("cold", &m, 6, 4).unwrap().unwrap();
        assert!(e / c < 0.2, "elastic {e} vs cold {c}");
    }

    #[test]
    fn scale_down_faster_than_scale_up_for_elastic() {
        // Fewer transfers are needed when shrinking (Appendix E).
        let m = dsv2_lite();
        let down = down_latency("elastic", &m, 6, 4).unwrap().unwrap();
        let up = super::super::fig7::scale_latency("elastic", &m, 4, 6)
            .unwrap()
            .unwrap();
        assert!(down <= up * 1.1, "down {down} vs up {up}");
    }
}
