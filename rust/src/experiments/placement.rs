//! Placement experiment (beyond the paper): round-robin vs load-aware vs
//! load-aware + hot-expert replication on a Zipf(1.0)-skewed routing
//! trace, across an EP reconfiguration (DSv2-Lite, 4 → 6 devices).
//!
//! The trace pins the hot experts onto ids that co-locate under the boot
//! placement (`e % ep`) — the adversarial-but-common case the placement
//! subsystem exists for: round-robin redistribution has no defense when
//! popularity correlates with id blocks, and any placement produced by
//! earlier minimal-movement scalings preserves such correlations.
//!
//! Reported per variant: expert-migration P2P bytes, peak per-device
//! token load on a held-out trace, max/mean imbalance, and simulated
//! decode throughput after the event (via [`CostModel`]'s `ep_imbalance`
//! term). Throughput *during* the event equals the pre-scale EP4 figure —
//! the old instance keeps serving through the concurrent HMM/IMM phase.

use std::cell::RefCell;
use std::rc::Rc;

use anyhow::Result;

use crate::config::model::dsv2_lite;
use crate::config::ParallelConfig;
use crate::device::{Cluster, DeviceId, Timings};
use crate::engine::moe::Routing;
use crate::engine::CostModel;
use crate::hmm::control::{HmmControl, HmmOptions};
use crate::placement::{replicate_hot, PlacementMode};
use crate::util::table::{f, Table};
use crate::workload::ZipfRouting;

use super::common::KV_BYTES;

const ZIPF_S: f64 = 1.0;
const TOKENS_PER_STEP: usize = 64;
const HBM: u64 = 64 << 30;

/// One placement variant's outcome.
pub struct VariantResult {
    pub label: String,
    /// Expert weights moved over the fabric by the scaling plan (plus
    /// replica copies for the replication variant).
    pub expert_p2p_bytes: u64,
    /// Peak per-device token load over the held-out trace.
    pub peak_tokens: usize,
    /// Max/mean per-device token load.
    pub imbalance: f64,
    /// Simulated decode throughput at EP6 under that imbalance.
    pub rps_after: f64,
}

/// The full comparison (shared trace, shared boot state).
pub struct PlacementComparison {
    /// Configured discretionary migration budget (expert bytes).
    pub budget_bytes: u64,
    /// Pre-scale EP4 throughput — also the throughput *during* the event,
    /// since the old instance serves through the concurrent phase.
    pub rps_before: f64,
    pub imbalance_before: f64,
    pub round_robin: VariantResult,
    pub load_aware: VariantResult,
    pub replicated: VariantResult,
}

/// Popularity rank → expert id: rank `r` maps to expert `(r % 16) * 4 +
/// r / 16`, so the 16 hottest experts are exactly the ids `≡ 0 (mod 4)` —
/// one EP4 boot rank's full expert set.
fn hot_block_mapping(n_experts: usize) -> Vec<usize> {
    let quarter = n_experts / 4;
    (0..n_experts).map(|r| (r % quarter) * 4 + r / quarter).collect()
}

fn single(owner: &[DeviceId]) -> Vec<Vec<DeviceId>> {
    owner.iter().map(|&d| vec![d]).collect()
}

pub fn compare(fast: bool) -> Result<PlacementComparison> {
    let m = dsv2_lite();
    let n_exp = m.n_experts as usize;
    let (warm_steps, eval_steps) = if fast { (120, 80) } else { (400, 200) };
    let from = ParallelConfig::standard(2, 2, (0..4).collect())?;
    let to = ParallelConfig::standard(3, 2, (0..6).collect())?;
    // Discretionary budget: 40 experts per layer — above the balanced
    // minimum for 4 → 6 (~22/layer) yet a real cap on churn.
    let budget = 40 * m.n_layers * m.expert_bytes();

    let mut gate = ZipfRouting::with_rank_mapping(
        n_exp,
        m.top_k as usize,
        ZIPF_S,
        1234,
        hot_block_mapping(n_exp),
    );
    let warm: Vec<Routing> =
        (0..warm_steps).map(|_| gate.step(TOKENS_PER_STEP)).collect();
    let eval: Vec<Routing> =
        (0..eval_steps).map(|_| gate.step(TOKENS_PER_STEP)).collect();

    // Peak/imbalance of an owner map over the held-out trace.
    let measure = |owners: &[Vec<DeviceId>], n_dev: usize| -> (usize, f64) {
        let mut totals = vec![0usize; n_dev];
        for r in &eval {
            let (c, dropped) = r.tokens_per_device_replicated(owners, n_dev);
            debug_assert_eq!(dropped, 0, "owner map out of range");
            for (t, x) in totals.iter_mut().zip(c) {
                *t += x;
            }
        }
        let peak = *totals.iter().max().unwrap();
        let loads: Vec<f64> = totals.iter().map(|&t| t as f64).collect();
        (peak, crate::placement::imbalance(&loads))
    };

    // Booted EP4 HMM with popularity stats warmed on the shared trace.
    let build = |mode: PlacementMode| -> Result<HmmControl> {
        let cluster = Rc::new(RefCell::new(Cluster::cloudmatrix(6)));
        let mut hmm =
            HmmControl::new(cluster, m.clone(), HmmOptions::default());
        hmm.placement.mode = mode;
        hmm.placement.migration_budget_bytes = budget;
        // Enough slack that no device is forced over capacity at EP6
        // (old devices hold 16 experts; ceil(64/6) + 5 = 16): every move
        // the load-aware plan makes is discretionary, so its expert P2P
        // bytes are bounded by the budget by construction.
        hmm.placement.capacity_slack = 5;
        hmm.load_initial(&from, KV_BYTES)?;
        for r in &warm {
            for layer in 0..m.n_layers as usize {
                hmm.record_routing(layer, r);
            }
        }
        Ok(hmm)
    };

    let cost = CostModel::new(m.clone(), Timings::cloudmatrix());

    // Pre-scale state is identical for every variant.
    let hmm_rr = build(PlacementMode::MinMove)?;
    let owners0 = single(hmm_rr.expert_owners(0).unwrap());
    let (_, imbalance_before) = measure(&owners0, 4);
    let rps_before = cost
        .clone()
        .with_ep_imbalance(imbalance_before)
        .steady_throughput_rps(&from, HBM, 2000, 600);

    // Execute the scaling event under one placement mode and measure the
    // resulting layer-0 owner map (all layers saw identical stats).
    let run_variant =
        |mut hmm: HmmControl, label: &str| -> Result<(VariantResult, HmmControl)> {
            let plan = hmm.plan_scale(&to)?;
            debug_assert!(plan.migrations_have_matching_evictions());
            let moved =
                plan.migrated_expert_count() as u64 * m.expert_bytes();
            hmm.execute_plan(&plan, &to)?;
            hmm.apply_deferred_frees()?;
            let owners = single(hmm.expert_owners(0).unwrap());
            let (peak_tokens, imbalance) = measure(&owners, 6);
            let rps_after = cost
                .clone()
                .with_ep_imbalance(imbalance)
                .steady_throughput_rps(&to, HBM, 2000, 600);
            Ok((
                VariantResult {
                    label: label.to_string(),
                    expert_p2p_bytes: moved,
                    peak_tokens,
                    imbalance,
                    rps_after,
                },
                hmm,
            ))
        };

    let (round_robin, _) = run_variant(hmm_rr, "round-robin (min-move)")?;
    let (load_aware, hmm_la) =
        run_variant(build(PlacementMode::LoadAware)?, "load-aware")?;

    // Replication overlay on the load-aware placement: grant the hottest
    // experts extra owners, router picks the least-loaded replica.
    let loads0 = hmm_la.load_stats().unwrap().predicted(0).to_vec();
    let owner0 = hmm_la.expert_owners(0).unwrap().to_vec();
    let capacity = n_exp.div_ceil(to.devices.len())
        + hmm_la.placement.capacity_slack;
    let owners_repl =
        replicate_hot(&owner0, &loads0, &to.devices, 6, capacity);
    let n_replicas: usize =
        owners_repl.iter().map(|os| os.len() - 1).sum();
    let (peak_tokens, imbalance) = measure(&owners_repl, 6);
    let rps_after = cost
        .clone()
        .with_ep_imbalance(imbalance)
        .steady_throughput_rps(&to, HBM, 2000, 600);
    let replicated = VariantResult {
        label: format!("load-aware + replicate x{n_replicas}"),
        expert_p2p_bytes: load_aware.expert_p2p_bytes
            + n_replicas as u64 * m.n_layers * m.expert_bytes(),
        peak_tokens,
        imbalance,
        rps_after,
    };

    Ok(PlacementComparison {
        budget_bytes: budget,
        rps_before,
        imbalance_before,
        round_robin,
        load_aware,
        replicated,
    })
}

/// Render the `repro exp placement` report.
pub fn run(opts: &super::common::ExpOptions) -> Result<String> {
    let fast = opts.fast;
    let c = compare(fast)?;
    let gb = |b: u64| b as f64 / (1u64 << 30) as f64;
    let mut report = String::new();
    let mut t = Table::new(
        "Expert placement under Zipf(1.0) routing — DSv2-Lite, EP4 -> EP6",
    )
    .header([
        "placement",
        "expert p2p GB",
        "peak dev tokens",
        "max/mean",
        "rps after",
    ]);
    for v in [&c.round_robin, &c.load_aware, &c.replicated] {
        t.row([
            v.label.clone(),
            f(gb(v.expert_p2p_bytes), 2),
            v.peak_tokens.to_string(),
            f(v.imbalance, 2),
            f(v.rps_after, 2),
        ]);
    }
    report.push_str(&t.render());
    report.push_str(&format!(
        "\nDuring the event the old EP4 instance keeps serving: {:.2} rps \
         at max/mean {:.2}. Migration budget: {:.1} GB of expert weights \
         (plans above stay within it by construction).\n\
         Expected shape: count-balanced round-robin leaves the hot-expert \
         block on one device (high peak load, slow hot rank); load-aware \
         placement spreads it for similar migration bytes, cutting peak \
         load and lifting post-scale throughput; replication splits the \
         hottest experts across owners to shave the residual peak.\n",
        c.rps_before,
        c.imbalance_before,
        gb(c.budget_bytes),
    ));
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// ISSUE acceptance: on the Zipf(1.0) trace, load-aware placement
    /// strictly beats round-robin on peak per-device load and post-scale
    /// throughput, within the configured migration budget.
    #[test]
    fn load_aware_beats_round_robin_on_zipf_trace() {
        let c = compare(true).unwrap();
        assert!(
            c.load_aware.peak_tokens < c.round_robin.peak_tokens,
            "peak load: load-aware {} vs round-robin {}",
            c.load_aware.peak_tokens,
            c.round_robin.peak_tokens
        );
        assert!(
            c.load_aware.rps_after > c.round_robin.rps_after,
            "rps: load-aware {} vs round-robin {}",
            c.load_aware.rps_after,
            c.round_robin.rps_after
        );
        assert!(
            c.load_aware.expert_p2p_bytes <= c.budget_bytes,
            "migration bytes {} exceed budget {}",
            c.load_aware.expert_p2p_bytes,
            c.budget_bytes
        );
        // Replication never loses to single ownership (small tolerance
        // for held-out-trace noise on the online replica pick).
        assert!(
            c.replicated.peak_tokens as f64
                <= c.load_aware.peak_tokens as f64 * 1.05,
            "replication peak {} vs load-aware {}",
            c.replicated.peak_tokens,
            c.load_aware.peak_tokens
        );
        // The skew the subsystem fixes is really there.
        assert!(c.round_robin.imbalance > 1.5, "{}", c.round_robin.imbalance);
        assert!(c.load_aware.imbalance < c.round_robin.imbalance);
    }

    #[test]
    fn placement_report_renders() {
        let r = run(&super::common::ExpOptions::fast(true)).unwrap();
        assert!(r.contains("round-robin"));
        assert!(r.contains("load-aware"));
        assert!(r.contains("replicate"));
        assert!(r.contains("Migration budget"));
    }
}
