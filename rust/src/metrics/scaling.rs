//! Scaling-event metrics (§7.3): scaling latency, downtime, peak memory —
//! the rows of Fig 7/8/12 and Tables 1/3.

/// Measured outcome of one scaling event.
#[derive(Debug, Clone, Default)]
pub struct ScalingMetrics {
    pub method: String,
    pub from_devices: usize,
    pub to_devices: usize,
    /// Command issued -> new instance ready to serve.
    pub scale_latency: f64,
    /// Interval with no serving instance available.
    pub downtime: f64,
    /// Peak memory summed across all involved NPUs during the event, bytes.
    pub peak_memory: u64,
    /// Devices occupied at the transition's worst moment (Extravagant
    /// holds old+new simultaneously).
    pub peak_devices: usize,
    /// Stage breakdown (name, seconds) for Fig 11.
    pub stages: Vec<(String, f64)>,
    /// Measured stage *placement*: `(name, start, end)` offsets in
    /// seconds relative to the scale command. Populated by methods whose
    /// stages genuinely overlap serving (ElasticMoE, from the HMM's
    /// `ScaleStats`); empty for the serial baselines, whose `stages`
    /// list laid end-to-end is already the true timeline. Consumed by
    /// [`crate::obs::SpanTracker::scaling_event`].
    pub stage_marks: Vec<(String, f64, f64)>,
}

impl ScalingMetrics {
    pub fn new(method: &str, from: usize, to: usize) -> Self {
        ScalingMetrics {
            method: method.to_string(),
            from_devices: from,
            to_devices: to,
            ..Default::default()
        }
    }

    pub fn stage(&mut self, name: &str, secs: f64) {
        self.stages.push((name.to_string(), secs));
    }

    /// Record a stage's measured `[start, end]` placement relative to
    /// the scale command (seconds).
    pub fn stage_mark(&mut self, name: &str, start: f64, end: f64) {
        self.stage_marks.push((name.to_string(), start, end));
    }

    pub fn stage_total(&self) -> f64 {
        self.stages.iter().map(|(_, t)| t).sum()
    }

    /// Peak memory in GB (paper table units).
    pub fn peak_gb(&self) -> f64 {
        self.peak_memory as f64 / (1u64 << 30) as f64
    }

    pub fn label(&self) -> String {
        format!(
            "{} {}→{}",
            self.method, self.from_devices, self.to_devices
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_accounting() {
        let mut m = ScalingMetrics::new("elastic", 4, 6);
        m.stage("p2p", 0.5);
        m.stage("warmup", 4.2);
        assert!((m.stage_total() - 4.7).abs() < 1e-12);
        m.peak_memory = 275 * (1 << 30);
        assert!((m.peak_gb() - 275.0).abs() < 1e-9);
        assert_eq!(m.label(), "elastic 4→6");
    }
}
