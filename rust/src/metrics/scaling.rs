//! Scaling-event metrics (§7.3): scaling latency, downtime, peak memory —
//! the rows of Fig 7/8/12 and Tables 1/3.

/// Measured outcome of one scaling event.
#[derive(Debug, Clone, Default)]
pub struct ScalingMetrics {
    pub method: String,
    pub from_devices: usize,
    pub to_devices: usize,
    /// Command issued -> new instance ready to serve.
    pub scale_latency: f64,
    /// Interval with no serving instance available.
    pub downtime: f64,
    /// Peak memory summed across all involved NPUs during the event, bytes.
    pub peak_memory: u64,
    /// Devices occupied at the transition's worst moment (Extravagant
    /// holds old+new simultaneously).
    pub peak_devices: usize,
    /// Stage breakdown (name, seconds) for Fig 11.
    pub stages: Vec<(String, f64)>,
}

impl ScalingMetrics {
    pub fn new(method: &str, from: usize, to: usize) -> Self {
        ScalingMetrics {
            method: method.to_string(),
            from_devices: from,
            to_devices: to,
            ..Default::default()
        }
    }

    pub fn stage(&mut self, name: &str, secs: f64) {
        self.stages.push((name.to_string(), secs));
    }

    pub fn stage_total(&self) -> f64 {
        self.stages.iter().map(|(_, t)| t).sum()
    }

    /// Peak memory in GB (paper table units).
    pub fn peak_gb(&self) -> f64 {
        self.peak_memory as f64 / (1u64 << 30) as f64
    }

    pub fn label(&self) -> String {
        format!(
            "{} {}→{}",
            self.method, self.from_devices, self.to_devices
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_accounting() {
        let mut m = ScalingMetrics::new("elastic", 4, 6);
        m.stage("p2p", 0.5);
        m.stage("warmup", 4.2);
        assert!((m.stage_total() - 4.7).abs() < 1e-12);
        m.peak_memory = 275 * (1 << 30);
        assert!((m.peak_gb() - 275.0).abs() < 1e-9);
        assert_eq!(m.label(), "elastic 4→6");
    }
}
