//! Request-level metric recording and windowed aggregation.

use std::cell::{Cell, RefCell};

use crate::config::SloConfig;
use crate::workload::Request;

/// One finished request's metrics.
#[derive(Debug, Clone, Copy)]
pub struct RequestMetrics {
    /// Request id (each request must finish exactly once — switchover
    /// handoffs adopt or restart, never duplicate; see
    /// `rust/tests/integration.rs`).
    pub id: u64,
    pub arrival: f64,
    pub finished: f64,
    pub ttft: f64,
    pub tpot: f64,
    pub tokens: usize,
    pub dropped: bool,
    /// Owning tenant (0 for single-tenant runs).
    pub tenant: u32,
}

/// Aggregated stats over a time window.
#[derive(Debug, Clone, Copy, Default)]
pub struct WindowStats {
    pub completed: usize,
    pub dropped: usize,
    pub throughput_rps: f64,
    pub tokens_per_sec: f64,
    pub slo_attainment: f64,
    pub mean_ttft: f64,
    pub p99_ttft: f64,
    pub mean_tpot: f64,
}

/// Key identifying one aggregation pass: records seen, window bounds
/// (bit-exact), and whether the window selects by arrival or finish time.
type SortKey = (usize, u64, u64, bool);

/// Sorted non-dropped TTFTs of the most recent aggregation window.
#[derive(Debug)]
struct SortedTtfts {
    key: SortKey,
    ttfts: Vec<f64>,
}

/// Collects per-request metrics across a run.
#[derive(Debug, Default)]
pub struct MetricsRecorder {
    finished: Vec<RequestMetrics>,
    /// One-entry cache so the percentile helpers sort once per
    /// aggregation pass instead of clone-and-sorting on every query.
    /// Keyed on `finished.len()`, so `record` invalidates it implicitly.
    sorted: RefCell<Option<SortedTtfts>>,
    /// Sorts performed (regression probe: repeated queries over an
    /// unchanged window must not re-sort).
    sorts: Cell<u64>,
}

impl MetricsRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    /// A recorder pre-sized for `n` finished requests. The simulators know
    /// their workload size up front, so sizing here keeps the record path
    /// free of reallocation.
    pub fn with_capacity(n: usize) -> Self {
        MetricsRecorder {
            finished: Vec::with_capacity(n),
            ..Default::default()
        }
    }

    /// Record a finished (or dropped) request. A dropped request is
    /// attributed to its stamped drop time (the engine stamps
    /// `finished_at` at the drop site); the arrival fallback exists only
    /// for unstamped records — prefer [`Self::record_dropped`] with the
    /// actual drop time, since back-dating a drop to its arrival puts it
    /// in a window that can be arbitrarily earlier under a long queue.
    pub fn record(&mut self, r: &Request) {
        let dropped =
            matches!(r.state, crate::workload::RequestState::Dropped);
        if dropped {
            self.record_dropped(r, r.finished_at.unwrap_or(r.arrival));
            return;
        }
        self.finished.push(RequestMetrics {
            id: r.id,
            arrival: r.arrival,
            finished: r.finished_at.unwrap_or(r.arrival),
            ttft: r.ttft().unwrap_or(f64::INFINITY),
            tpot: r.tpot().unwrap_or(f64::INFINITY),
            tokens: r.generated,
            dropped,
            tenant: r.tenant,
        });
    }

    /// Record a request dropped at `at`: finish-time-windowed stats
    /// count the drop in the window it actually happened in.
    pub fn record_dropped(&mut self, r: &Request, at: f64) {
        self.finished.push(RequestMetrics {
            id: r.id,
            arrival: r.arrival,
            finished: at,
            ttft: r.ttft().unwrap_or(f64::INFINITY),
            tpot: r.tpot().unwrap_or(f64::INFINITY),
            tokens: r.generated,
            dropped: true,
            tenant: r.tenant,
        });
    }

    pub fn count(&self) -> usize {
        self.finished.len()
    }

    pub fn all(&self) -> &[RequestMetrics] {
        &self.finished
    }

    /// Sorted TTFTs of non-dropped requests whose arrival (or finish,
    /// per `by_arrival`) falls in `[t0, t1)`. Sorted at most once per
    /// aggregation pass; repeat queries over the same window reuse the
    /// cached order.
    fn with_sorted_ttfts<R>(
        &self,
        t0: f64,
        t1: f64,
        by_arrival: bool,
        f: impl FnOnce(&[f64]) -> R,
    ) -> R {
        let key: SortKey =
            (self.finished.len(), t0.to_bits(), t1.to_bits(), by_arrival);
        let mut cache = self.sorted.borrow_mut();
        if cache.as_ref().map(|c| c.key) != Some(key) {
            let mut ttfts: Vec<f64> = self
                .finished
                .iter()
                .filter(|m| {
                    let t = if by_arrival { m.arrival } else { m.finished };
                    t >= t0 && t < t1 && !m.dropped
                })
                .map(|m| m.ttft)
                .collect();
            ttfts.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorts.set(self.sorts.get() + 1);
            *cache = Some(SortedTtfts { key, ttfts });
        }
        f(&cache.as_ref().unwrap().ttfts)
    }

    /// Stats over requests that *finished* within `[t0, t1)`.
    pub fn window(&self, t0: f64, t1: f64, slo: &SloConfig) -> WindowStats {
        let in_window: Vec<&RequestMetrics> = self
            .finished
            .iter()
            .filter(|m| m.finished >= t0 && m.finished < t1)
            .collect();
        let dur = (t1 - t0).max(1e-9);
        let completed: Vec<&&RequestMetrics> =
            in_window.iter().filter(|m| !m.dropped).collect();
        let dropped = in_window.len() - completed.len();
        if in_window.is_empty() {
            return WindowStats::default();
        }
        let met = in_window
            .iter()
            .filter(|m| !m.dropped && slo.met(m.ttft, m.tpot))
            .count();
        let tpots: Vec<f64> = completed.iter().map(|m| m.tpot).collect();
        let (mean_ttft, p99_ttft) =
            self.with_sorted_ttfts(t0, t1, false, |s| {
                (
                    crate::util::stats::mean(s),
                    crate::util::stats::percentile_sorted(s, 99.0),
                )
            });
        WindowStats {
            completed: completed.len(),
            dropped,
            throughput_rps: completed.len() as f64 / dur,
            tokens_per_sec: completed.iter().map(|m| m.tokens).sum::<usize>()
                as f64
                / dur,
            slo_attainment: met as f64 / in_window.len() as f64,
            mean_ttft,
            p99_ttft,
            mean_tpot: crate::util::stats::mean(&tpots),
        }
    }

    /// SLO attainment over requests *arriving* in `[t0, t1)` — the paper's
    /// timeline plots bucket by arrival.
    pub fn attainment_by_arrival(
        &self,
        t0: f64,
        t1: f64,
        slo: &SloConfig,
    ) -> f64 {
        let arrived: Vec<&RequestMetrics> = self
            .finished
            .iter()
            .filter(|m| m.arrival >= t0 && m.arrival < t1)
            .collect();
        if arrived.is_empty() {
            return f64::NAN;
        }
        let met = arrived
            .iter()
            .filter(|m| !m.dropped && slo.met(m.ttft, m.tpot))
            .count();
        met as f64 / arrived.len() as f64
    }

    /// TTFT percentile over requests *arriving* in `[t0, t1)` — the
    /// KV-handoff experiments measure the scaling window this way, so a
    /// drained-and-recomputed in-flight sequence (whose TTFT restarts)
    /// lands in the same bucket as its arrival cohort. NaN when the
    /// window is empty.
    pub fn ttft_percentile_by_arrival(
        &self,
        t0: f64,
        t1: f64,
        pct: f64,
    ) -> f64 {
        self.with_sorted_ttfts(t0, t1, true, |s| {
            crate::util::stats::percentile_sorted(s, pct)
        })
    }

    /// SLO attainment for one tenant over the whole run, judged against
    /// that tenant's own SLO (multi-tenant fleets sell different SLOs).
    /// NaN when the tenant sent no traffic.
    pub fn attainment_for_tenant(
        &self,
        tenant: u32,
        slo: &SloConfig,
    ) -> f64 {
        let theirs: Vec<&RequestMetrics> = self
            .finished
            .iter()
            .filter(|m| m.tenant == tenant)
            .collect();
        if theirs.is_empty() {
            return f64::NAN;
        }
        let met = theirs
            .iter()
            .filter(|m| !m.dropped && slo.met(m.ttft, m.tpot))
            .count();
        met as f64 / theirs.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{Request, RequestState};

    fn finished_req(
        id: u64,
        arrival: f64,
        ttft: f64,
        tpot: f64,
        n: usize,
    ) -> Request {
        let mut r = Request::new(id, arrival, 100, n);
        r.first_token_at = Some(arrival + ttft);
        r.finished_at = Some(arrival + ttft + tpot * (n - 1) as f64);
        r.generated = n;
        r.state = RequestState::Finished;
        r
    }

    #[test]
    fn window_stats() {
        let slo = SloConfig::new(1.0, 0.5);
        let mut rec = MetricsRecorder::new();
        rec.record(&finished_req(1, 0.0, 0.5, 0.1, 11)); // meets SLO
        rec.record(&finished_req(2, 1.0, 2.0, 0.1, 11)); // TTFT violation
        let mut dropped = Request::new(3, 2.0, 100, 10);
        dropped.state = RequestState::Dropped;
        dropped.finished_at = Some(2.0);
        rec.record(&dropped);

        let w = rec.window(0.0, 100.0, &slo);
        assert_eq!(w.completed, 2);
        assert_eq!(w.dropped, 1);
        assert!((w.slo_attainment - 1.0 / 3.0).abs() < 1e-9);
        assert!(w.tokens_per_sec > 0.0);
    }

    #[test]
    fn windowed_drop_lands_in_its_drop_window() {
        // A request that queued from t=2 and was shed at t=50 is a drop
        // of the [40, 60) window — the old `finished = arrival` fallback
        // misattributed it to [0, 10).
        let slo = SloConfig::new(1.0, 0.5);
        let mut rec = MetricsRecorder::new();
        let queued = Request::new(7, 2.0, 100, 10);
        rec.record_dropped(&queued, 50.0);
        assert_eq!(rec.window(0.0, 10.0, &slo).dropped, 0);
        assert_eq!(rec.window(40.0, 60.0, &slo).dropped, 1);
        // `record` routes a stamped Dropped request the same way.
        let mut stamped = Request::new(8, 2.0, 100, 10);
        stamped.state = RequestState::Dropped;
        stamped.finished_at = Some(55.0);
        rec.record(&stamped);
        assert_eq!(rec.window(0.0, 10.0, &slo).dropped, 0);
        assert_eq!(rec.window(40.0, 60.0, &slo).dropped, 2);
    }

    #[test]
    fn attainment_per_tenant_uses_that_tenants_slo() {
        let mut rec = MetricsRecorder::new();
        let mut fast = finished_req(1, 0.0, 0.5, 0.1, 5);
        fast.tenant = 0;
        let mut slow = finished_req(2, 0.0, 3.0, 0.1, 5);
        slow.tenant = 1;
        rec.record(&fast);
        rec.record(&slow);
        let strict = SloConfig::new(1.0, 1.0);
        let relaxed = SloConfig::new(5.0, 1.0);
        assert_eq!(rec.attainment_for_tenant(0, &strict), 1.0);
        assert_eq!(rec.attainment_for_tenant(1, &strict), 0.0);
        assert_eq!(rec.attainment_for_tenant(1, &relaxed), 1.0);
        assert!(rec.attainment_for_tenant(9, &strict).is_nan());
    }

    #[test]
    fn ttft_percentile_by_arrival_windows() {
        let mut rec = MetricsRecorder::new();
        rec.record(&finished_req(1, 5.0, 0.2, 0.1, 5));
        rec.record(&finished_req(2, 6.0, 8.0, 0.1, 5));
        rec.record(&finished_req(3, 20.0, 0.3, 0.1, 5));
        let p99 = rec.ttft_percentile_by_arrival(0.0, 10.0, 99.0);
        assert!(p99 >= 7.9, "{p99}");
        let p99_late = rec.ttft_percentile_by_arrival(15.0, 25.0, 99.0);
        assert!(p99_late < 1.0, "{p99_late}");
        assert!(rec.ttft_percentile_by_arrival(30.0, 40.0, 99.0).is_nan());
        // Ids ride along for uniqueness checks.
        assert_eq!(rec.all()[0].id, 1);
    }

    #[test]
    fn repeated_window_queries_do_not_resort() {
        let slo = SloConfig::new(1.0, 0.5);
        let mut rec = MetricsRecorder::new();
        for i in 0..32 {
            rec.record(&finished_req(i, i as f64 * 0.1, 0.5, 0.1, 5));
        }
        let first = rec.window(0.0, 100.0, &slo);
        let sorts = rec.sorts.get();
        assert_eq!(sorts, 1);
        for _ in 0..10 {
            let again = rec.window(0.0, 100.0, &slo);
            assert_eq!(again.p99_ttft, first.p99_ttft);
            assert_eq!(again.mean_ttft, first.mean_ttft);
        }
        assert_eq!(rec.sorts.get(), sorts, "repeat queries re-sorted");
        // A different window (or selection mode) is a new pass.
        let _ = rec.ttft_percentile_by_arrival(0.0, 100.0, 99.0);
        assert_eq!(rec.sorts.get(), sorts + 1);
        // Recording invalidates the cache via the length key.
        rec.record(&finished_req(99, 0.0, 0.5, 0.1, 5));
        let _ = rec.window(0.0, 100.0, &slo);
        assert_eq!(rec.sorts.get(), sorts + 2);
    }

    #[test]
    fn attainment_by_arrival_buckets() {
        let slo = SloConfig::new(1.0, 1.0);
        let mut rec = MetricsRecorder::new();
        rec.record(&finished_req(1, 5.0, 0.1, 0.1, 5));
        rec.record(&finished_req(2, 15.0, 9.9, 0.1, 5));
        assert_eq!(rec.attainment_by_arrival(0.0, 10.0, &slo), 1.0);
        assert_eq!(rec.attainment_by_arrival(10.0, 20.0, &slo), 0.0);
        assert!(rec.attainment_by_arrival(30.0, 40.0, &slo).is_nan());
    }
}
