//! Serving metrics (§7.3): TTFT, TPOT, SLO attainment, SLO-per-NPU,
//! windowed throughput, and scaling-event metrics (scale latency, downtime,
//! peak memory).

pub mod recorder;
pub mod scaling;

pub use recorder::{MetricsRecorder, WindowStats};
pub use scaling::ScalingMetrics;
