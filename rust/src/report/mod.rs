//! `repro report` — the postmortem generator.
//!
//! Turns an instrumented experiment run into one byte-deterministic
//! markdown document answering the three questions an on-call engineer
//! asks after an autoscaling incident:
//!
//! 1. **What did scaling cost?** Per scaling event: the
//!    concurrent-phase vs switchover-window time split (the paper's
//!    central claim is that the first dwarfs the second), the
//!    device-seconds held while the transition was in flight, and the
//!    SLO attainment immediately before and after
//!    ([`crate::obs::attain`]).
//! 2. **Why did the policy act?** The decision ledger: every
//!    [`TraceEvent::DecisionExplain`] record the estimator/policy
//!    emitted — observed load, hysteresis counters, cooldown state,
//!    the chosen action, and whether a capacity guard vetoed it — plus
//!    the reconciler's checked no-ops (steps refused as duplicate or
//!    already satisfied).
//! 3. **Can I reproduce it?** Any cell that tripped an invariant or
//!    absorbed an injected fault gets a postmortem section with a
//!    replay bundle: seed, exact replay command, expected `state_hash`
//!    and the trailing trace window, as one JSON object. Running the
//!    embedded command reproduces the identical hash (determinism
//!    contract, `rust/tests/determinism.rs`).
//!
//! The renderer is a pure function of [`ReportInput`] — no clocks, no
//! maps with nondeterministic order — so the same seed yields the same
//! bytes, pinned by the golden file `rust/tests/golden/report.md` and
//! the determinism suite. See `docs/architecture/11-reporting.md`.

use std::collections::BTreeSet;

use anyhow::{bail, Result};

use crate::chaos::{Trace, TraceEvent, Violation};
use crate::config::SloConfig;
use crate::experiments::{
    chaos as chaos_exp, disagg as disagg_exp, reconcile as reconcile_exp,
};
use crate::metrics::recorder::RequestMetrics;
use crate::obs::spans::{CAT_CONCURRENT, CAT_SWITCHOVER};
use crate::obs::{attain, Telemetry};
use crate::util::json::{self, Json};

/// Attainment-timeline window width, seconds.
pub const WINDOW: f64 = 20.0;
/// Burn-rate horizon, seconds.
pub const BURN_HORIZON: f64 = 60.0;
/// Trace events kept in a replay bundle's trailing window.
pub const TRAIL: usize = 12;
/// Decision-ledger rows rendered before eliding steady-state holds.
const LEDGER_CAP: usize = 40;
/// Leading ledger rows always shown (context before the first action).
const SHOW_HEAD: usize = 6;
/// Reconciler no-op rows rendered before eliding.
const NOOP_CAP: usize = 20;

/// Everything the renderer needs; building one of these is the side
/// that runs simulations, rendering is pure.
#[derive(Debug, Clone)]
pub struct ReportInput {
    pub experiment: String,
    pub seed: u64,
    pub fast: bool,
    /// The command line that (re)generates this report.
    pub invocation: String,
    pub slo: SloConfig,
    pub cells: Vec<CellReport>,
    pub ledger: Option<LedgerReport>,
    /// Ingested Prometheus exposition lines (`name value`), verbatim.
    pub metrics: Vec<String>,
}

/// One experiment cell (method × direction × fault, or pool layout).
#[derive(Debug, Clone)]
pub struct CellReport {
    pub name: String,
    /// 16-hex-digit run digest.
    pub state_hash: String,
    pub end_time: f64,
    pub arrived: usize,
    /// Requests with a recorded disposition (finished or dropped).
    pub completed: usize,
    /// Timeline window width used for this cell, seconds.
    pub window: f64,
    /// Caveats (e.g. ingested artifact without per-request latency).
    pub notes: Vec<String>,
    pub events: Vec<EventReport>,
    pub timeline: Vec<TimelineRow>,
    pub violations: Vec<String>,
    pub postmortem: Option<Postmortem>,
}

/// One scaling event: time split, cost, attainment bracket.
#[derive(Debug, Clone)]
pub struct EventReport {
    pub event: usize,
    pub start: f64,
    pub done: f64,
    /// Seconds spent in concurrent-phase spans (NaN = no telemetry).
    pub concurrent_s: f64,
    /// Seconds spent inside the switchover window (NaN = no telemetry).
    pub switchover_s: f64,
    /// Device-seconds held over `[start, done]`.
    pub device_seconds: f64,
    pub attainment_before: f64,
    pub attainment_after: f64,
    /// `completed`, `aborted+rolled-back`, or `aborted`.
    pub outcome: String,
}

/// One attainment series (a tenant or a pool partition).
#[derive(Debug, Clone)]
pub struct TimelineRow {
    pub key: String,
    pub windows: Vec<attain::WindowAttainment>,
    /// Burn rate at the end of the run over [`BURN_HORIZON`].
    pub burn: f64,
}

/// One policy tick from a [`TraceEvent::DecisionExplain`] record.
#[derive(Debug, Clone)]
pub struct LedgerEntry {
    pub t: f64,
    pub pool: String,
    pub serving: usize,
    /// Estimator-fed attainment; `-1` encodes NaN (no traffic).
    pub attainment: f64,
    pub occupancy: f64,
    pub queue: usize,
    pub bad: usize,
    pub good: usize,
    pub cooling: bool,
    pub rearmed: bool,
    pub reburst: bool,
    pub decision: String,
    pub action: String,
    pub vetoed: bool,
}

impl LedgerEntry {
    /// Anything other than a steady-state hold.
    fn acting(&self) -> bool {
        self.vetoed || self.decision != "hold" || self.action != "hold"
    }
}

/// A reconcile step enacted as a checked no-op (`applied: false`).
#[derive(Debug, Clone)]
pub struct NoopStep {
    pub t: f64,
    pub replica: usize,
    pub step: String,
}

/// The decision-ledger section: policy ticks plus reconciler guards.
#[derive(Debug, Clone)]
pub struct LedgerReport {
    pub source: String,
    pub replay: String,
    pub state_hash: String,
    pub entries: Vec<LedgerEntry>,
    pub noops: Vec<NoopStep>,
    pub violations: Vec<String>,
}

/// The replayable incident bundle.
#[derive(Debug, Clone)]
pub struct Postmortem {
    pub verdict: String,
    pub replay: String,
    pub state_hash: String,
    pub violations: Vec<String>,
    /// One-line JSON: seed, replay command, expected hash, trailing
    /// trace window, violations.
    pub bundle: String,
}

// ---------------------------------------------------------------------
// Formatting helpers (fixed precision keeps the bytes deterministic).

fn ft(x: f64) -> String {
    format!("{x:.3}")
}

/// Attainment-style value: NaN and the `-1` no-traffic encoding render
/// as `n/a`.
fn fa3(x: f64) -> String {
    if x.is_nan() || x < 0.0 {
        "n/a".to_string()
    } else {
        format!("{x:.3}")
    }
}

fn fd(x: f64) -> String {
    if x.is_nan() {
        "n/a".to_string()
    } else {
        format!("{x:.1}")
    }
}

fn hex16(h: u64) -> String {
    format!("{h:016x}")
}

/// The command that replays an experiment run.
pub fn replay_command(experiment: &str, seed: u64, fast: bool) -> String {
    format!(
        "repro exp {experiment} --seed {seed}{}",
        if fast { " --fast" } else { "" }
    )
}

fn invocation(experiment: &str, seed: u64, fast: bool) -> String {
    format!(
        "repro report {experiment} --seed {seed}{}",
        if fast { " --fast" } else { "" }
    )
}

/// Serialize a replay bundle as one JSON line (keys BTreeMap-sorted by
/// [`Json`], so the bytes are stable).
pub fn replay_bundle(
    experiment: &str,
    cell: &str,
    seed: u64,
    fast: bool,
    state_hash: &str,
    trail: &[Json],
    violations: &[String],
) -> String {
    Json::obj(vec![
        ("cell", Json::str(cell)),
        ("experiment", Json::str(experiment)),
        ("fast", Json::Bool(fast)),
        ("replay", Json::str(replay_command(experiment, seed, fast))),
        ("seed", Json::num(seed as f64)),
        ("state_hash", Json::str(state_hash)),
        ("trail", Json::arr(trail.iter().cloned())),
        ("violations", Json::arr(violations.iter().map(|v| Json::str(v.as_str())))),
    ])
    .to_string()
}

// ---------------------------------------------------------------------
// Builders: trace/recorder -> report structs.

/// Scaling events paired from the trace: `(event, start, done, outcome)`.
/// An event with a command but no terminal record (run truncated
/// mid-transition) is skipped — it has no cost bracket to report.
fn scaling_events(trace: &Trace) -> Vec<(usize, f64, f64, String)> {
    let mut starts: Vec<(usize, f64)> = Vec::new();
    let mut out = Vec::new();
    for ev in &trace.events {
        match ev {
            TraceEvent::ScaleCommand { t, event, .. } => {
                starts.push((*event, *t));
            }
            TraceEvent::ScaleCompleted { t, event, .. } => {
                if let Some(&(_, s)) =
                    starts.iter().find(|&&(e, _)| e == *event)
                {
                    out.push((*event, s, *t, "completed".to_string()));
                }
            }
            TraceEvent::ScaleAborted {
                t,
                event,
                rolled_back,
                ..
            } => {
                if let Some(&(_, s)) =
                    starts.iter().find(|&&(e, _)| e == *event)
                {
                    let outcome = if *rolled_back {
                        "aborted+rolled-back"
                    } else {
                        "aborted"
                    };
                    out.push((*event, s, *t, outcome.to_string()));
                }
            }
            _ => {}
        }
    }
    out
}

/// Borrowed view over one run's outputs — the bridge from
/// [`crate::coordinator::SimOutput`] / [`crate::coordinator::FleetOutput`]
/// (which share these fields but not a trait) into [`cell_report`].
pub struct CellSource<'a> {
    pub name: &'a str,
    pub arrived: usize,
    pub reqs: &'a [RequestMetrics],
    pub trace: &'a Trace,
    pub state_hash: u64,
    pub end_time: f64,
    pub device_timeline: &'a [(f64, usize)],
    pub telemetry: Option<&'a Telemetry>,
    pub violations: &'a [Violation],
}

/// Build one cell's report: event costs, attainment timelines (per
/// tenant, plus per pool when the trace shows prefill→decode handoffs),
/// and — when an invariant tripped or a fault fired — the postmortem
/// replay bundle.
pub fn cell_report(
    src: &CellSource,
    slo: &SloConfig,
    experiment: &str,
    seed: u64,
    fast: bool,
) -> CellReport {
    let triples = scaling_events(src.trace);
    let spans: Vec<(usize, f64, f64)> =
        triples.iter().map(|&(e, s, d, _)| (e, s, d)).collect();
    let costs = attain::event_costs(
        src.reqs,
        slo,
        src.device_timeline,
        &spans,
        WINDOW,
        src.end_time,
    );
    let events: Vec<EventReport> = triples
        .iter()
        .zip(costs.iter())
        .map(|(&(event, start, done, ref outcome), c)| {
            let (mut concurrent_s, mut switchover_s) = (f64::NAN, f64::NAN);
            if let Some(tel) = src.telemetry {
                let evs = tel.spans.for_event(event);
                concurrent_s = evs
                    .iter()
                    .filter(|s| s.cat == CAT_CONCURRENT)
                    .map(|s| s.end - s.start)
                    .sum();
                switchover_s = evs
                    .iter()
                    .filter(|s| s.cat == CAT_SWITCHOVER)
                    .map(|s| s.end - s.start)
                    .sum();
            }
            EventReport {
                event,
                start,
                done,
                concurrent_s,
                switchover_s,
                device_seconds: c.device_seconds,
                attainment_before: c.attainment_before,
                attainment_after: c.attainment_after,
                outcome: outcome.clone(),
            }
        })
        .collect();

    let mut timeline: Vec<TimelineRow> = Vec::new();
    for (key, ws) in attain::per_tenant(src.reqs, slo, WINDOW, src.end_time)
    {
        let burn = attain::burn_rate(
            &ws,
            slo.target_attainment,
            BURN_HORIZON,
            src.end_time,
        );
        timeline.push(TimelineRow { key, windows: ws, burn });
    }
    // Pool partition: requests whose KV crossed prefill→decode vs those
    // served where they prefilled (only meaningful when handoffs exist).
    let handoff: BTreeSet<u64> = src
        .trace
        .events
        .iter()
        .filter_map(|e| match e {
            TraceEvent::HandoffPlanned { id, .. } => Some(*id),
            _ => None,
        })
        .collect();
    if !handoff.is_empty() {
        for (key, ws) in
            attain::windows_by(src.reqs, slo, WINDOW, src.end_time, |m| {
                Some(if handoff.contains(&m.id) {
                    "pool:prefill>decode".to_string()
                } else {
                    "pool:local".to_string()
                })
            })
        {
            let burn = attain::burn_rate(
                &ws,
                slo.target_attainment,
                BURN_HORIZON,
                src.end_time,
            );
            timeline.push(TimelineRow { key, windows: ws, burn });
        }
    }

    let violations: Vec<String> =
        src.violations.iter().map(|v| v.to_string()).collect();
    let fault_fired = src
        .trace
        .count(|e| matches!(e, TraceEvent::FaultFired { .. }))
        > 0;
    let aborted = events.iter().any(|e| e.outcome.starts_with("aborted"));
    let postmortem = if !violations.is_empty() || fault_fired || aborted {
        let hash = hex16(src.state_hash);
        let tail_from = src.trace.events.len().saturating_sub(TRAIL);
        let trail: Vec<Json> = src.trace.events[tail_from..]
            .iter()
            .map(|e| e.to_json())
            .collect();
        Some(Postmortem {
            verdict: if violations.is_empty() {
                "fault injected and recovered; no invariant violations \
                 (bundle kept for replay)"
                    .to_string()
            } else {
                "invariant violations — replay the bundle to reproduce"
                    .to_string()
            },
            replay: replay_command(experiment, seed, fast),
            state_hash: hash.clone(),
            violations: violations.clone(),
            bundle: replay_bundle(
                experiment, src.name, seed, fast, &hash, &trail, &violations,
            ),
        })
    } else {
        None
    };

    CellReport {
        name: src.name.to_string(),
        state_hash: hex16(src.state_hash),
        end_time: src.end_time,
        arrived: src.arrived,
        completed: src.reqs.len(),
        window: WINDOW,
        notes: Vec::new(),
        events,
        timeline,
        violations,
        postmortem,
    }
}

/// Harvest the decision ledger from a trace: every
/// [`TraceEvent::DecisionExplain`] tick plus the reconciler's checked
/// no-ops.
pub fn ledger_from_trace(
    source: &str,
    replay: &str,
    trace: &Trace,
    state_hash: u64,
    violations: &[Violation],
) -> LedgerReport {
    let mut entries = Vec::new();
    let mut noops = Vec::new();
    for ev in &trace.events {
        match ev {
            TraceEvent::DecisionExplain {
                t,
                pool,
                serving,
                attainment,
                occupancy,
                queue,
                bad_windows,
                good_windows,
                cooling,
                rearmed,
                reburst,
                decision,
                action,
                vetoed,
            } => entries.push(LedgerEntry {
                t: *t,
                pool: pool.to_string(),
                serving: *serving,
                attainment: *attainment,
                occupancy: *occupancy,
                queue: *queue,
                bad: *bad_windows,
                good: *good_windows,
                cooling: *cooling,
                rearmed: *rearmed,
                reburst: *reburst,
                decision: decision.to_string(),
                action: action.clone(),
                vetoed: *vetoed,
            }),
            TraceEvent::ReconcileStep {
                t,
                replica,
                step,
                applied: false,
            } => noops.push(NoopStep {
                t: *t,
                replica: *replica,
                step: step.clone(),
            }),
            _ => {}
        }
    }
    LedgerReport {
        source: source.to_string(),
        replay: replay.to_string(),
        state_hash: hex16(state_hash),
        entries,
        noops,
        violations: violations.iter().map(|v| v.to_string()).collect(),
    }
}

// ---------------------------------------------------------------------
// Experiment entry points.

/// Run `experiment` fully instrumented and build its report input.
pub fn build(experiment: &str, seed: u64, fast: bool) -> Result<ReportInput> {
    match experiment {
        "chaos" => build_chaos(seed, fast),
        "disagg" => build_disagg(seed, fast),
        "reconcile" => build_reconcile(seed, fast),
        other => bail!(
            "`repro report` runs for: chaos, disagg, reconcile \
             (got '{other}'); any run's exported artifacts can be \
             ingested instead via `repro report ingest --trace <file> \
             [--metrics <file>]`"
        ),
    }
}

/// Run `experiment` and render the finished markdown.
pub fn generate(experiment: &str, seed: u64, fast: bool) -> Result<String> {
    Ok(render(&build(experiment, seed, fast)?))
}

fn build_chaos(seed: u64, fast: bool) -> Result<ReportInput> {
    let slo = chaos_exp::report_slo();
    let raw = chaos_exp::report_cells(seed, fast)?;
    let cells = raw
        .iter()
        .map(|c| {
            cell_report(
                &CellSource {
                    name: &c.name,
                    arrived: c.arrived,
                    reqs: c.out.recorder.all(),
                    trace: &c.out.trace,
                    state_hash: c.out.state_hash,
                    end_time: c.out.end_time,
                    device_timeline: &c.out.device_timeline,
                    telemetry: c.out.telemetry.as_ref(),
                    violations: &c.violations,
                },
                &slo,
                "chaos",
                seed,
                fast,
            )
        })
        .collect();
    // The chaos matrix scales on a manual trigger, so the decision
    // ledger rides on the reconcile experiment's duplicate-command leg
    // — the one run where the estimator, the policy guards and the
    // reconciler's no-op marks all land on a single trace.
    let (lo, lv) = reconcile_exp::ledger_run(seed, fast)?;
    let ledger = ledger_from_trace(
        "reconcile duplicate-command leg",
        &replay_command("reconcile", seed, fast),
        &lo.trace,
        lo.state_hash,
        &lv,
    );
    Ok(ReportInput {
        experiment: "chaos".to_string(),
        seed,
        fast,
        invocation: invocation("chaos", seed, fast),
        slo,
        cells,
        ledger: Some(ledger),
        metrics: Vec::new(),
    })
}

fn build_disagg(seed: u64, fast: bool) -> Result<ReportInput> {
    let slo = disagg_exp::report_slo();
    let raw = disagg_exp::report_cells(seed, fast)?;
    let cells: Vec<CellReport> = raw
        .iter()
        .map(|c| {
            cell_report(
                &CellSource {
                    name: &c.name,
                    arrived: c.arrived,
                    reqs: c.out.recorder.all(),
                    trace: &c.out.trace,
                    state_hash: c.out.state_hash,
                    end_time: c.out.end_time,
                    device_timeline: &c.out.device_timeline,
                    telemetry: c.out.telemetry.as_ref(),
                    violations: &c.violations,
                },
                &slo,
                "disagg",
                seed,
                fast,
            )
        })
        .collect();
    // The disagg fleet is pinned (the policy holds every tick), so its
    // own per-pool explains are the ledger.
    let ledger = raw
        .iter()
        .find(|c| {
            c.out
                .trace
                .count(|e| matches!(e, TraceEvent::DecisionExplain { .. }))
                > 0
        })
        .map(|c| {
            ledger_from_trace(
                &format!("disagg fleet policy (cell `{}`)", c.name),
                &replay_command("disagg", seed, fast),
                &c.out.trace,
                c.out.state_hash,
                &c.violations,
            )
        });
    Ok(ReportInput {
        experiment: "disagg".to_string(),
        seed,
        fast,
        invocation: invocation("disagg", seed, fast),
        slo,
        cells,
        ledger,
        metrics: Vec::new(),
    })
}

fn build_reconcile(seed: u64, fast: bool) -> Result<ReportInput> {
    let slo = reconcile_exp::report_slo();
    let (out, violations) = reconcile_exp::ledger_run(seed, fast)?;
    let arrived = out
        .trace
        .count(|e| matches!(e, TraceEvent::Arrival { .. }));
    let cell = cell_report(
        &CellSource {
            name: "elastic/duplicate-command",
            arrived,
            reqs: out.recorder.all(),
            trace: &out.trace,
            state_hash: out.state_hash,
            end_time: out.end_time,
            device_timeline: &out.device_timeline,
            telemetry: out.telemetry.as_ref(),
            violations: &violations,
        },
        &slo,
        "reconcile",
        seed,
        fast,
    );
    let ledger = ledger_from_trace(
        "reconcile duplicate-command leg",
        &replay_command("reconcile", seed, fast),
        &out.trace,
        out.state_hash,
        &violations,
    );
    Ok(ReportInput {
        experiment: "reconcile".to_string(),
        seed,
        fast,
        invocation: invocation("reconcile", seed, fast),
        slo,
        cells: vec![cell],
        ledger: Some(ledger),
        metrics: Vec::new(),
    })
}

// ---------------------------------------------------------------------
// Artifact ingestion (`--trace-out` / `--metrics-out` products).

/// Build a report from previously exported artifacts instead of a live
/// run. `trace_text` accepts either rendering the repo produces: the
/// raw [`Trace`] JSON (`{"events": [...], "state_hash": "..."}`) or
/// the Chrome trace-event export (`{"traceEvents": [...]}`).
/// `metrics_text` is the Prometheus exposition, included verbatim.
pub fn ingest(
    label: &str,
    trace_text: &str,
    metrics_text: Option<&str>,
) -> Result<ReportInput> {
    let doc = json::parse(trace_text)?;
    let (cell, ledger) = if doc.get("events").as_arr().is_some() {
        ingest_raw_trace(label, &doc)
    } else if doc.get("traceEvents").as_arr().is_some() {
        ingest_chrome_trace(label, &doc)
    } else {
        bail!(
            "unrecognized trace artifact: expected a raw trace \
             ({{\"events\": ...}}) or a Chrome trace-event export \
             ({{\"traceEvents\": ...}})"
        );
    };
    let metrics = metrics_text
        .map(|t| {
            t.lines()
                .filter(|l| !l.is_empty() && !l.starts_with('#'))
                .map(str::to_string)
                .collect()
        })
        .unwrap_or_default();
    Ok(ReportInput {
        experiment: format!("ingest:{label}"),
        seed: 0,
        fast: false,
        invocation: format!("repro report ingest --trace {label}"),
        slo: SloConfig::new(f64::NAN, f64::NAN),
        cells: vec![cell],
        ledger,
        metrics,
    })
}

/// Raw trace JSON: rebuild the event table from
/// `scale_command`/`scale_completed`/`scale_aborted` records (the
/// declared pause window stands in for the switchover split) and the
/// ledger from `decision_explain` records.
fn ingest_raw_trace(
    label: &str,
    doc: &Json,
) -> (CellReport, Option<LedgerReport>) {
    let events_json = doc.get("events").as_arr().unwrap_or(&[]);
    let state_hash = doc
        .get("state_hash")
        .as_str()
        .unwrap_or("unknown")
        .to_string();
    let mut end_time: f64 = 0.0;
    let mut starts: Vec<(usize, f64, f64)> = Vec::new(); // (event, t, pause)
    let mut events: Vec<EventReport> = Vec::new();
    let mut entries: Vec<LedgerEntry> = Vec::new();
    let mut noops: Vec<NoopStep> = Vec::new();
    let mut arrived = 0usize;
    for e in events_json {
        let t = e.get("t").as_f64().unwrap_or(0.0);
        end_time = end_time.max(t);
        match e.get("ev").as_str().unwrap_or("") {
            "arrival" => arrived += 1,
            "scale_command" => {
                let ev = e.get("event").as_usize().unwrap_or(0);
                let pause = match e.get("declared_pause").as_arr() {
                    Some(p) if p.len() == 2 => {
                        p[1].as_f64().unwrap_or(0.0)
                            - p[0].as_f64().unwrap_or(0.0)
                    }
                    _ => f64::NAN,
                };
                starts.push((ev, t, pause));
            }
            kind @ ("scale_completed" | "scale_aborted") => {
                let ev = e.get("event").as_usize().unwrap_or(0);
                if let Some(&(_, s, pause)) =
                    starts.iter().find(|&&(id, _, _)| id == ev)
                {
                    let outcome = if kind == "scale_completed" {
                        "completed".to_string()
                    } else if e.get("rolled_back").as_bool() == Some(true) {
                        "aborted+rolled-back".to_string()
                    } else {
                        "aborted".to_string()
                    };
                    let switchover_s = pause;
                    let concurrent_s = if pause.is_nan() {
                        f64::NAN
                    } else {
                        (t - s - pause).max(0.0)
                    };
                    events.push(EventReport {
                        event: ev,
                        start: s,
                        done: t,
                        concurrent_s,
                        switchover_s,
                        device_seconds: f64::NAN,
                        attainment_before: f64::NAN,
                        attainment_after: f64::NAN,
                        outcome,
                    });
                }
            }
            "decision_explain" => entries.push(LedgerEntry {
                t,
                pool: e.get("pool").as_str().unwrap_or("?").to_string(),
                serving: e.get("serving").as_usize().unwrap_or(0),
                attainment: e.get("attainment").as_f64().unwrap_or(-1.0),
                occupancy: e.get("occupancy").as_f64().unwrap_or(0.0),
                queue: e.get("queue").as_usize().unwrap_or(0),
                bad: e.get("bad_windows").as_usize().unwrap_or(0),
                good: e.get("good_windows").as_usize().unwrap_or(0),
                cooling: e.get("cooling").as_bool().unwrap_or(false),
                rearmed: e.get("rearmed").as_bool().unwrap_or(false),
                reburst: e.get("reburst").as_bool().unwrap_or(false),
                decision: e
                    .get("decision")
                    .as_str()
                    .unwrap_or("?")
                    .to_string(),
                action: e.get("action").as_str().unwrap_or("?").to_string(),
                vetoed: e.get("vetoed").as_bool().unwrap_or(false),
            }),
            "reconcile_step" => {
                if e.get("applied").as_bool() == Some(false) {
                    noops.push(NoopStep {
                        t,
                        replica: e.get("replica").as_usize().unwrap_or(0),
                        step: e
                            .get("step")
                            .as_str()
                            .unwrap_or("?")
                            .to_string(),
                    });
                }
            }
            _ => {}
        }
    }
    let ledger = if entries.is_empty() && noops.is_empty() {
        None
    } else {
        Some(LedgerReport {
            source: format!("ingested trace `{label}`"),
            replay: "n/a (ingested artifact)".to_string(),
            state_hash: state_hash.clone(),
            entries,
            noops,
            violations: Vec::new(),
        })
    };
    let cell = CellReport {
        name: label.to_string(),
        state_hash,
        end_time,
        arrived,
        completed: 0,
        window: WINDOW,
        notes: vec![
            "ingested trace artifact: per-request latency is not \
             recorded in the trace, so attainment timelines and \
             device-second costs are unavailable (switchover time is \
             the declared pause window)"
                .to_string(),
        ],
        events,
        timeline: Vec::new(),
        violations: Vec::new(),
        postmortem: None,
    };
    (cell, ledger)
}

/// Chrome trace-event export: rebuild the concurrent/switchover split
/// from the `X` span events (which carry `args.event` and `args.cat`);
/// timestamps are microseconds.
fn ingest_chrome_trace(
    label: &str,
    doc: &Json,
) -> (CellReport, Option<LedgerReport>) {
    let span_events = doc.get("traceEvents").as_arr().unwrap_or(&[]);
    // event id -> (start_us, end_us, concurrent_us, switchover_us)
    let mut by_event: Vec<(usize, f64, f64, f64, f64)> = Vec::new();
    let mut end_time: f64 = 0.0;
    for e in span_events {
        if e.get("ph").as_str() != Some("X") {
            continue;
        }
        let ts = e.get("ts").as_f64().unwrap_or(0.0);
        let dur = e.get("dur").as_f64().unwrap_or(0.0);
        end_time = end_time.max((ts + dur) / 1e6);
        let args = e.get("args");
        let ev = match args.get("event").as_usize() {
            Some(ev) => ev,
            None => continue,
        };
        let cat = e.get("cat").as_str().unwrap_or("");
        let idx = match by_event.iter().position(|r| r.0 == ev) {
            Some(i) => i,
            None => {
                by_event.push((ev, f64::INFINITY, 0.0, 0.0, 0.0));
                by_event.len() - 1
            }
        };
        let slot = &mut by_event[idx];
        slot.1 = slot.1.min(ts);
        slot.2 = slot.2.max(ts + dur);
        if cat == CAT_CONCURRENT {
            slot.3 += dur;
        } else if cat == CAT_SWITCHOVER {
            slot.4 += dur;
        }
    }
    by_event.sort_by_key(|r| r.0);
    let events = by_event
        .iter()
        .map(|&(ev, s, d, c, w)| EventReport {
            event: ev,
            start: s / 1e6,
            done: d / 1e6,
            concurrent_s: c / 1e6,
            switchover_s: w / 1e6,
            device_seconds: f64::NAN,
            attainment_before: f64::NAN,
            attainment_after: f64::NAN,
            outcome: "(see trace)".to_string(),
        })
        .collect();
    let cell = CellReport {
        name: label.to_string(),
        state_hash: "unknown".to_string(),
        end_time,
        arrived: 0,
        completed: 0,
        window: WINDOW,
        notes: vec![
            "ingested Chrome trace-event artifact: spans only — \
             request-level attainment, device-second costs and the \
             decision ledger are not part of this export"
                .to_string(),
        ],
        events,
        timeline: Vec::new(),
        violations: Vec::new(),
        postmortem: None,
    };
    (cell, None)
}

// ---------------------------------------------------------------------
// Rendering.

/// Render the report. Pure: same input, same bytes (golden-pinned by
/// `rust/tests/golden/report.md`).
pub fn render(input: &ReportInput) -> String {
    let mut out: Vec<String> = Vec::new();
    out.push(format!("# repro report — {}", input.experiment));
    out.push(String::new());
    out.push(format!("- invocation: `{}`", input.invocation));
    out.push(format!("- seed: {}", input.seed));
    if input.slo.ttft.is_nan() {
        out.push("- SLO: (unknown — ingested artifact)".to_string());
    } else {
        out.push(format!(
            "- SLO: TTFT <= {}s, TPOT <= {}s, target attainment {:.0}%",
            ft(input.slo.ttft),
            ft(input.slo.tpot),
            input.slo.target_attainment * 100.0
        ));
    }
    out.push(format!("- cells: {}", input.cells.len()));
    for cell in &input.cells {
        render_cell(cell, &mut out);
    }
    if let Some(l) = &input.ledger {
        render_ledger(l, &mut out);
    }
    if !input.metrics.is_empty() {
        out.push(String::new());
        out.push("## Metrics snapshot (ingested)".to_string());
        out.push(String::new());
        out.push("```".to_string());
        for m in &input.metrics {
            out.push(m.clone());
        }
        out.push("```".to_string());
    }
    out.push(String::new());
    out.join("\n")
}

fn render_cell(cell: &CellReport, out: &mut Vec<String>) {
    out.push(String::new());
    out.push(format!("## Cell `{}`", cell.name));
    out.push(String::new());
    out.push(format!("- state hash: `{}`", cell.state_hash));
    out.push(format!(
        "- horizon: {}s; requests: {} arrived, {} recorded",
        ft(cell.end_time),
        cell.arrived,
        cell.completed
    ));
    out.push(format!("- invariant violations: {}", cell.violations.len()));
    for n in &cell.notes {
        out.push(format!("- note: {n}"));
    }
    out.push(String::new());
    out.push("### Scaling events — concurrent vs switchover".to_string());
    out.push(String::new());
    if cell.events.is_empty() {
        out.push("(no scaling events)".to_string());
    } else {
        out.push(
            "| event | start (s) | ready (s) | total (s) | concurrent (s) \
             | switchover (s) | device-s | attain before | attain after \
             | outcome |"
                .to_string(),
        );
        out.push("|---|---|---|---|---|---|---|---|---|---|".to_string());
        for e in &cell.events {
            out.push(format!(
                "| {} | {} | {} | {} | {} | {} | {} | {} | {} | {} |",
                e.event,
                ft(e.start),
                ft(e.done),
                ft(e.done - e.start),
                fa3(e.concurrent_s),
                fa3(e.switchover_s),
                fd(e.device_seconds),
                fa3(e.attainment_before),
                fa3(e.attainment_after),
                e.outcome
            ));
        }
    }
    if !cell.timeline.is_empty() {
        out.push(String::new());
        out.push(format!(
            "### Attainment timeline ({:.0}s windows; burn rate over \
             trailing {:.0}s)",
            cell.window, BURN_HORIZON
        ));
        for row in &cell.timeline {
            out.push(String::new());
            out.push(format!("**{}** — burn rate {:.2}", row.key, row.burn));
            out.push(String::new());
            out.push(
                "| window (s) | arrived | attained | violated | in-flight \
                 | attainment | scaling |"
                    .to_string(),
            );
            out.push("|---|---|---|---|---|---|---|".to_string());
            for w in &row.windows {
                let marks: Vec<String> = cell
                    .events
                    .iter()
                    .filter(|e| e.start >= w.t0 && e.start < w.t1)
                    .map(|e| {
                        format!("#{} ({} dev-s)", e.event, fd(e.device_seconds))
                    })
                    .collect();
                let scaling = if marks.is_empty() {
                    "-".to_string()
                } else {
                    marks.join(", ")
                };
                out.push(format!(
                    "| [{:.0}, {:.0}) | {} | {} | {} | {} | {} | {} |",
                    w.t0,
                    w.t1,
                    w.arrived,
                    w.attained,
                    w.violated,
                    w.in_flight,
                    fa3(w.attainment()),
                    scaling
                ));
            }
        }
    }
    if let Some(p) = &cell.postmortem {
        out.push(String::new());
        out.push("### Postmortem".to_string());
        out.push(String::new());
        out.push(format!("- verdict: {}", p.verdict));
        out.push(format!("- replay: `{}`", p.replay));
        out.push(format!("- expected state hash: `{}`", p.state_hash));
        out.push(format!("- violations: {}", p.violations.len()));
        for v in &p.violations {
            out.push(format!("  - {v}"));
        }
        out.push(String::new());
        out.push("Replay bundle:".to_string());
        out.push(String::new());
        out.push("```json".to_string());
        out.push(p.bundle.clone());
        out.push("```".to_string());
    }
}

fn render_ledger(l: &LedgerReport, out: &mut Vec<String>) {
    out.push(String::new());
    out.push("## Decision ledger".to_string());
    out.push(String::new());
    out.push(format!("- source: {} (`{}`)", l.source, l.replay));
    out.push(format!("- state hash: `{}`", l.state_hash));
    let acting = l.entries.iter().filter(|e| e.acting()).count();
    let vetoed = l.entries.iter().filter(|e| e.vetoed).count();
    out.push(format!(
        "- entries: {} (acting: {}, vetoed: {}); reconciler checked \
         no-ops: {}",
        l.entries.len(),
        acting,
        vetoed,
        l.noops.len()
    ));
    if !l.violations.is_empty() {
        out.push(format!("- invariant violations: {}", l.violations.len()));
        for v in &l.violations {
            out.push(format!("  - {v}"));
        }
    }
    out.push(String::new());
    if l.entries.is_empty() {
        out.push("(no policy ticks recorded)".to_string());
    } else {
        out.push(
            "| t (s) | pool | serving | attain | occupancy | queue | bad \
             | good | flags | decision | action | vetoed |"
                .to_string(),
        );
        out.push("|---|---|---|---|---|---|---|---|---|---|---|---|".to_string());
        let mut show: Vec<usize> =
            (0..l.entries.len().min(SHOW_HEAD)).collect();
        for (i, e) in l.entries.iter().enumerate() {
            if e.acting() && !show.contains(&i) {
                show.push(i);
            }
        }
        show.sort_unstable();
        show.truncate(LEDGER_CAP);
        for &i in &show {
            let e = &l.entries[i];
            let mut flags: Vec<&str> = Vec::new();
            if e.cooling {
                flags.push("cooling");
            }
            if e.rearmed {
                flags.push("rearmed");
            }
            if e.reburst {
                flags.push("reburst");
            }
            let flags = if flags.is_empty() {
                "-".to_string()
            } else {
                flags.join("+")
            };
            out.push(format!(
                "| {} | {} | {} | {} | {} | {} | {} | {} | {} | {} | {} | {} |",
                ft(e.t),
                e.pool,
                e.serving,
                fa3(e.attainment),
                fa3(e.occupancy),
                e.queue,
                e.bad,
                e.good,
                flags,
                e.decision,
                e.action,
                if e.vetoed { "yes" } else { "-" }
            ));
        }
        let elided = l.entries.len() - show.len();
        if elided > 0 {
            out.push(String::new());
            out.push(format!("({elided} steady-state hold entries elided)"));
        }
    }
    if !l.noops.is_empty() {
        out.push(String::new());
        out.push(
            "### Reconciler guard no-ops (steps refused as duplicate or \
             already satisfied)"
                .to_string(),
        );
        out.push(String::new());
        out.push("| t (s) | replica | step |".to_string());
        out.push("|---|---|---|".to_string());
        for n in l.noops.iter().take(NOOP_CAP) {
            out.push(format!(
                "| {} | {} | {} |",
                ft(n.t),
                n.replica,
                n.step
            ));
        }
        if l.noops.len() > NOOP_CAP {
            out.push(String::new());
            out.push(format!("({} no-ops elided)", l.noops.len() - NOOP_CAP));
        }
    }
}

// ---------------------------------------------------------------------
// Golden fixture.

/// The hand-built canonical report input: two cells (one clean with a
/// completed event and a tenant timeline, one faulted with a
/// postmortem bundle) plus a three-entry decision ledger with a vetoed
/// action and one reconciler no-op. Every number is chosen so the
/// rendered bytes are hand-checkable; `rust/tests/golden/report.md`
/// pins them.
pub fn sample_input() -> ReportInput {
    let trail = vec![TraceEvent::ScaleAborted {
        t: 43.0,
        event: 0,
        rolled_back: true,
        reason: "p2p-link".to_string(),
    }
    .to_json()];
    let bundle = replay_bundle(
        "chaos",
        "elastic/up/p2p-link",
        23,
        true,
        "0000feedface0000",
        &trail,
        &[],
    );
    ReportInput {
        experiment: "chaos".to_string(),
        seed: 23,
        fast: true,
        invocation: "repro report chaos --seed 23 --fast".to_string(),
        slo: SloConfig::new(8.0, 1.5),
        cells: vec![
            CellReport {
                name: "elastic/up/none".to_string(),
                state_hash: "00000000deadbeef".to_string(),
                end_time: 160.0,
                arrived: 4,
                completed: 4,
                window: 20.0,
                notes: Vec::new(),
                events: vec![EventReport {
                    event: 0,
                    start: 40.0,
                    done: 52.5,
                    concurrent_s: 11.5,
                    switchover_s: 1.0,
                    device_seconds: 100.0,
                    attainment_before: 0.5,
                    attainment_after: 1.0,
                    outcome: "completed".to_string(),
                }],
                timeline: vec![TimelineRow {
                    key: "tenant:0".to_string(),
                    burn: 0.25,
                    windows: vec![
                        attain::WindowAttainment {
                            t0: 0.0,
                            t1: 20.0,
                            arrived: 2,
                            attained: 1,
                            violated: 1,
                            in_flight: 0,
                        },
                        attain::WindowAttainment {
                            t0: 40.0,
                            t1: 60.0,
                            arrived: 2,
                            attained: 2,
                            violated: 0,
                            in_flight: 0,
                        },
                    ],
                }],
                violations: Vec::new(),
                postmortem: None,
            },
            CellReport {
                name: "elastic/up/p2p-link".to_string(),
                state_hash: "0000feedface0000".to_string(),
                end_time: 160.0,
                arrived: 3,
                completed: 3,
                window: 20.0,
                notes: Vec::new(),
                events: vec![EventReport {
                    event: 0,
                    start: 40.0,
                    done: 43.0,
                    concurrent_s: f64::NAN,
                    switchover_s: f64::NAN,
                    device_seconds: 24.0,
                    attainment_before: 1.0,
                    attainment_after: f64::NAN,
                    outcome: "aborted+rolled-back".to_string(),
                }],
                timeline: Vec::new(),
                violations: Vec::new(),
                postmortem: Some(Postmortem {
                    verdict: "fault injected and recovered; no invariant \
                              violations (bundle kept for replay)"
                        .to_string(),
                    replay: "repro exp chaos --seed 23 --fast".to_string(),
                    state_hash: "0000feedface0000".to_string(),
                    violations: Vec::new(),
                    bundle,
                }),
            },
        ],
        ledger: Some(LedgerReport {
            source: "reconcile duplicate-command leg".to_string(),
            replay: "repro exp reconcile --seed 23 --fast".to_string(),
            state_hash: "0123456789abcdef".to_string(),
            entries: vec![
                LedgerEntry {
                    t: 60.5,
                    pool: "unified".to_string(),
                    serving: 2,
                    attainment: 0.612,
                    occupancy: 0.94,
                    queue: 12,
                    bad: 2,
                    good: 0,
                    cooling: false,
                    rearmed: false,
                    reburst: true,
                    decision: "up".to_string(),
                    action: "grow r0->4dev".to_string(),
                    vetoed: false,
                },
                LedgerEntry {
                    t: 61.0,
                    pool: "unified".to_string(),
                    serving: 2,
                    attainment: -1.0,
                    occupancy: 0.5,
                    queue: 0,
                    bad: 0,
                    good: 1,
                    cooling: true,
                    rearmed: false,
                    reburst: false,
                    decision: "hold".to_string(),
                    action: "hold".to_string(),
                    vetoed: false,
                },
                LedgerEntry {
                    t: 62.0,
                    pool: "unified".to_string(),
                    serving: 3,
                    attainment: 0.4,
                    occupancy: 0.97,
                    queue: 9,
                    bad: 3,
                    good: 0,
                    cooling: false,
                    rearmed: true,
                    reburst: false,
                    decision: "up".to_string(),
                    action: "hold".to_string(),
                    vetoed: true,
                },
            ],
            noops: vec![NoopStep {
                t: 62.5,
                replica: 1,
                step: "resize->4".to_string(),
            }],
            violations: Vec::new(),
        }),
        metrics: Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chaos::Trace;

    fn trace_with_events() -> Trace {
        let mut tr = Trace::new();
        tr.push(TraceEvent::ScaleCommand {
            t: 10.0,
            event: 0,
            from_devices: 4,
            to_devices: 6,
            declared_pause: Some((19.0, 19.5)),
        });
        tr.push(TraceEvent::ScaleCompleted {
            t: 20.0,
            event: 0,
            devices: 6,
        });
        tr.push(TraceEvent::ScaleCommand {
            t: 30.0,
            event: 1,
            from_devices: 6,
            to_devices: 8,
            declared_pause: None,
        });
        tr.push(TraceEvent::ScaleAborted {
            t: 33.0,
            event: 1,
            rolled_back: true,
            reason: "device-loss".to_string(),
        });
        tr
    }

    #[test]
    fn scaling_events_pair_commands_with_outcomes() {
        let evs = scaling_events(&trace_with_events());
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0], (0, 10.0, 20.0, "completed".to_string()));
        assert_eq!(
            evs[1],
            (1, 30.0, 33.0, "aborted+rolled-back".to_string())
        );
    }

    #[test]
    fn cell_report_builds_events_timeline_and_postmortem() {
        let tr = trace_with_events();
        let reqs = vec![
            RequestMetrics {
                id: 1,
                arrival: 5.0,
                finished: 6.0,
                ttft: 0.5,
                tpot: 0.1,
                tokens: 10,
                dropped: false,
                tenant: 0,
            },
            RequestMetrics {
                id: 2,
                arrival: 25.0,
                finished: 26.0,
                ttft: 99.0,
                tpot: 0.1,
                tokens: 10,
                dropped: false,
                tenant: 1,
            },
        ];
        let cell = cell_report(
            &CellSource {
                name: "elastic/up/device-loss",
                arrived: 2,
                reqs: &reqs,
                trace: &tr,
                state_hash: 0xabcd,
                end_time: 40.0,
                device_timeline: &[(0.0, 4), (20.0, 6)],
                telemetry: None,
                violations: &[],
            },
            &SloConfig::new(8.0, 1.5),
            "chaos",
            7,
            true,
        );
        assert_eq!(cell.events.len(), 2);
        // Event 0 spans [10, 20] at 4 devices.
        assert!((cell.events[0].device_seconds - 40.0).abs() < 1e-9);
        assert!(cell.events[0].concurrent_s.is_nan(), "no telemetry");
        assert_eq!(cell.timeline.len(), 2, "one row per tenant");
        assert_eq!(cell.state_hash, "000000000000abcd");
        // The abort makes it a fault cell: postmortem with a bundle
        // that parses and carries the seed and hash.
        let p = cell.postmortem.expect("aborted event => postmortem");
        assert_eq!(p.replay, "repro exp chaos --seed 7 --fast");
        let bundle = json::parse(&p.bundle).unwrap();
        assert_eq!(bundle.get("seed").as_u64(), Some(7));
        assert_eq!(
            bundle.get("state_hash").as_str(),
            Some("000000000000abcd")
        );
        assert_eq!(bundle.get("trail").as_arr().unwrap().len(), tr.len());
    }

    #[test]
    fn ledger_harvests_explains_and_noop_steps() {
        let mut tr = Trace::new();
        tr.push(TraceEvent::DecisionExplain {
            t: 5.0,
            pool: "unified",
            serving: 2,
            attainment: 0.8,
            occupancy: 0.7,
            queue: 3,
            bad_windows: 1,
            good_windows: 0,
            cooling: false,
            rearmed: false,
            reburst: false,
            decision: "up",
            action: "hold".to_string(),
            vetoed: true,
        });
        tr.push(TraceEvent::ReconcileStep {
            t: 6.0,
            replica: 1,
            step: "resize->4".to_string(),
            applied: false,
        });
        tr.push(TraceEvent::ReconcileStep {
            t: 7.0,
            replica: 1,
            step: "resize->4".to_string(),
            applied: true,
        });
        let l = ledger_from_trace("test", "repro exp x", &tr, 1, &[]);
        assert_eq!(l.entries.len(), 1);
        assert!(l.entries[0].vetoed);
        assert!(l.entries[0].acting());
        assert_eq!(l.noops.len(), 1, "applied steps are not no-ops");
    }

    #[test]
    fn render_is_pure_and_contains_the_contract_sections() {
        let input = sample_input();
        let a = render(&input);
        let b = render(&input);
        assert_eq!(a, b);
        for needle in [
            "# repro report — chaos",
            "## Cell `elastic/up/none`",
            "### Scaling events — concurrent vs switchover",
            "| 0 | 40.000 | 52.500 | 12.500 | 11.500 | 1.000 | 100.0 \
             | 0.500 | 1.000 | completed |",
            "### Attainment timeline (20s windows; burn rate over \
             trailing 60s)",
            "#0 (100.0 dev-s)",
            "### Postmortem",
            "Replay bundle:",
            "## Decision ledger",
            "| yes |",
            "### Reconciler guard no-ops",
        ] {
            assert!(a.contains(needle), "missing {needle:?} in:\n{a}");
        }
        assert!(a.ends_with('\n'));
    }

    #[test]
    fn ledger_elides_steady_state_holds_but_keeps_actions() {
        let hold = LedgerEntry {
            t: 0.0,
            pool: "unified".to_string(),
            serving: 1,
            attainment: 1.0,
            occupancy: 0.1,
            queue: 0,
            bad: 0,
            good: 1,
            cooling: false,
            rearmed: false,
            reburst: false,
            decision: "hold".to_string(),
            action: "hold".to_string(),
            vetoed: false,
        };
        let mut entries: Vec<LedgerEntry> =
            (0..30).map(|i| LedgerEntry { t: i as f64, ..hold.clone() }).collect();
        entries.push(LedgerEntry {
            t: 30.0,
            decision: "up".to_string(),
            action: "add-replica".to_string(),
            ..hold.clone()
        });
        let l = LedgerReport {
            source: "s".to_string(),
            replay: "r".to_string(),
            state_hash: "0".repeat(16),
            entries,
            noops: Vec::new(),
            violations: Vec::new(),
        };
        let mut out = Vec::new();
        render_ledger(&l, &mut out);
        let text = out.join("\n");
        assert!(text.contains("add-replica"), "{text}");
        assert!(text.contains("steady-state hold entries elided"), "{text}");
    }

    #[test]
    fn ingest_raw_trace_recovers_events_and_ledger() {
        let mut tr = trace_with_events();
        tr.push(TraceEvent::DecisionExplain {
            t: 9.0,
            pool: "unified",
            serving: 1,
            attainment: -1.0,
            occupancy: 0.9,
            queue: 5,
            bad_windows: 2,
            good_windows: 0,
            cooling: false,
            rearmed: false,
            reburst: false,
            decision: "up",
            action: "scale->6dev".to_string(),
            vetoed: false,
        });
        let text = format!("{}", tr.to_json());
        let input = ingest("run1", &text, Some("# TYPE x gauge\nx 1\n"))
            .unwrap();
        assert_eq!(input.cells.len(), 1);
        let cell = &input.cells[0];
        assert_eq!(cell.events.len(), 2);
        // Declared pause (19.0..19.5) stands in for the switchover.
        assert!((cell.events[0].switchover_s - 0.5).abs() < 1e-9);
        assert!((cell.events[0].concurrent_s - 9.5).abs() < 1e-9);
        let ledger = input.ledger.expect("explain record => ledger");
        assert_eq!(ledger.entries.len(), 1);
        assert_eq!(ledger.entries[0].action, "scale->6dev");
        assert_eq!(input.metrics, vec!["x 1".to_string()]);
        let text = render(&input);
        assert!(text.contains("## Metrics snapshot (ingested)"));
    }
}
