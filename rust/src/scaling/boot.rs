//! Shared cold-boot sequence for the vLLM-style baselines (and Fig 4a's
//! initialisation-latency breakdown): container start, engine
//! pre-initialisation, communication-group setup, disk weight load, KV
//! allocation, warmup.

use anyhow::Result;

use crate::config::{ModelConfig, ParallelConfig};
use crate::device::{Cluster, DeviceId, RegionId};
use crate::imm::instance::BootBreakdown;
use crate::imm::loader::disk_loader_boot;

/// Cold-boot an instance with the DiskLoader. Returns its private regions
/// and the per-stage breakdown.
pub fn cold_boot(
    cluster: &mut Cluster,
    model: &ModelConfig,
    parallel: &ParallelConfig,
    kv_bytes_per_device: u64,
    proc: u32,
) -> Result<(Vec<(DeviceId, RegionId)>, BootBreakdown)> {
    let t = cluster.timings.clone();
    let (regions, load_time) =
        disk_loader_boot(cluster, model, parallel, kv_bytes_per_device, proc)?;
    let kv_alloc = t.kv_alloc(kv_bytes_per_device);
    let breakdown = BootBreakdown {
        container: t.container_start,
        preinit: t.preinit_cpu,
        comm_init: t.comm_init(parallel.n_devices()),
        weight_load: load_time - kv_alloc,
        kv_alloc,
        attach: 0.0,
        warmup: t.warmup_for(model.n_layers),
    };
    Ok((regions, breakdown))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::dsv2_lite;

    #[test]
    fn cold_boot_breakdown_is_dominated_by_fixed_costs_and_load() {
        let mut c = Cluster::cloudmatrix(4);
        let m = dsv2_lite();
        let p = ParallelConfig::standard(2, 2, (0..4).collect()).unwrap();
        let (regions, b) = cold_boot(&mut c, &m, &p, 8 << 30, 1).unwrap();
        assert!(!regions.is_empty());
        // Fig 4a shape: total is tens of seconds; weight load and preinit
        // are the dominant stages.
        assert!(b.total() > 30.0, "total {}", b.total());
        assert!(b.weight_load > 3.0);
        assert!(b.preinit > b.warmup);
    }
}
