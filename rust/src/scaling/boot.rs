//! Boot sequences: the shared disk cold boot for the vLLM-style baselines
//! (and Fig 4a's initialisation-latency breakdown) — container start,
//! engine pre-initialisation, communication-group setup, disk weight
//! load, KV allocation, warmup — plus the DRAM-warm fast boot that skips
//! the container and reads weights from the host staging tier over h2d
//! instead of from disk (the unpark path of the tiered weight store).

use anyhow::Result;

use crate::config::{ModelConfig, ParallelConfig};
use crate::device::hbm::RegionKind;
use crate::device::{Cluster, DeviceId, RegionId};
use crate::imm::instance::BootBreakdown;
use crate::imm::loader::disk_loader_boot;

/// Cold-boot an instance with the DiskLoader. Returns its private regions
/// and the per-stage breakdown.
pub fn cold_boot(
    cluster: &mut Cluster,
    model: &ModelConfig,
    parallel: &ParallelConfig,
    kv_bytes_per_device: u64,
    proc: u32,
) -> Result<(Vec<(DeviceId, RegionId)>, BootBreakdown)> {
    let t = cluster.timings.clone();
    let (regions, load_time) =
        disk_loader_boot(cluster, model, parallel, kv_bytes_per_device, proc)?;
    let kv_alloc = t.kv_alloc(kv_bytes_per_device);
    let breakdown = BootBreakdown {
        container: t.container_start,
        preinit: t.preinit_cpu,
        comm_init: t.comm_init(parallel.n_devices()),
        weight_load: load_time - kv_alloc,
        kv_alloc,
        attach: 0.0,
        warmup: t.warmup_for(model.n_layers),
    };
    Ok((regions, breakdown))
}

/// DRAM-warm boot: the instance's weights are already staged in host
/// DRAM (a parked replica, or a prefetched standby), its process alive
/// and comm groups kept. The breakdown therefore drops the container
/// start, replaces CPU pre-init with the host-state restore, and pays
/// h2d bandwidth instead of disk for the weight load — activation costs
/// h2d + attach, not a cold read. Returns the instance's private regions
/// and the per-stage breakdown, directly comparable to [`cold_boot`].
pub fn dram_warm_boot(
    cluster: &mut Cluster,
    model: &ModelConfig,
    parallel: &ParallelConfig,
    kv_bytes_per_device: u64,
    proc: u32,
) -> Result<(Vec<(DeviceId, RegionId)>, BootBreakdown)> {
    use crate::hmm::weights::WeightLayout;

    let t = cluster.timings.clone();
    let layout = WeightLayout::compute(model, parallel);
    let mut regions = Vec::new();
    let mut worst: f64 = 0.0;
    for &dev in &parallel.devices {
        let weight_bytes = layout.device_bytes(dev);
        let r = cluster.devices[dev].hbm.alloc(
            weight_bytes,
            RegionKind::AttnWeights,
            false,
            format!("dramwarm:{proc}"),
        )?;
        regions.push((dev, r));
        let kv = cluster.devices[dev].hbm.alloc(
            kv_bytes_per_device,
            RegionKind::KvCache,
            false,
            format!("dramwarm-kv:{proc}"),
        )?;
        regions.push((dev, kv));
        // h2d lanes run per device in parallel.
        worst = worst.max(t.h2d(weight_bytes) + t.kv_alloc(kv_bytes_per_device));
    }
    let kv_alloc = t.kv_alloc(kv_bytes_per_device);
    let breakdown = BootBreakdown {
        container: 0.0,
        preinit: t.host_restore,
        comm_init: 0.0,
        weight_load: worst - kv_alloc,
        kv_alloc,
        attach: t.zero_copy_per_handle,
        warmup: t.warmup_for(model.n_layers),
    };
    Ok((regions, breakdown))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::dsv2_lite;

    #[test]
    fn dram_warm_boot_is_an_order_of_magnitude_under_cold() {
        let m = dsv2_lite();
        let p = ParallelConfig::standard(2, 2, (0..4).collect()).unwrap();
        let mut c1 = Cluster::cloudmatrix(4);
        let (_, cold) = cold_boot(&mut c1, &m, &p, 8 << 30, 1).unwrap();
        let mut c2 = Cluster::cloudmatrix(4);
        let (regions, warm) = dram_warm_boot(&mut c2, &m, &p, 8 << 30, 2).unwrap();
        assert!(!regions.is_empty());
        assert!(
            warm.total() * 5.0 < cold.total(),
            "warm {} vs cold {}",
            warm.total(),
            cold.total()
        );
        assert_eq!(warm.container, 0.0, "parked process stays alive");
        assert!(warm.preinit < cold.preinit / 10.0);
        assert!(warm.weight_load < cold.weight_load / 5.0, "h2d beats disk");
        assert_eq!(warm.warmup, cold.warmup, "warmup is unavoidable");
    }

    #[test]
    fn cold_boot_breakdown_is_dominated_by_fixed_costs_and_load() {
        let mut c = Cluster::cloudmatrix(4);
        let m = dsv2_lite();
        let p = ParallelConfig::standard(2, 2, (0..4).collect()).unwrap();
        let (regions, b) = cold_boot(&mut c, &m, &p, 8 << 30, 1).unwrap();
        assert!(!regions.is_empty());
        // Fig 4a shape: total is tens of seconds; weight load and preinit
        // are the dominant stages.
        assert!(b.total() > 30.0, "total {}", b.total());
        assert!(b.weight_load > 3.0);
        assert!(b.preinit > b.warmup);
    }
}
