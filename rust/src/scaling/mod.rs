//! Scaling methods: ElasticMoE plus the paper's four baselines (§7.2), all
//! serving through the same engine. Each method implements
//! [`ScalingMethod`]: boot an initial configuration, then execute scaling
//! events that produce measured [`crate::metrics::ScalingMetrics`] and a
//! transition timeline the serving simulator enacts.

pub mod baselines;
pub mod boot;
pub mod elastic;
pub mod outcome;

pub use baselines::{ColdRestart, Colocated, Extravagant, Horizontal};
pub use elastic::ElasticMoE;
pub use outcome::{ScaleAbort, ScalingMethod, ScalingOutcome};
