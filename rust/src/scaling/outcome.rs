//! The scaling-method interface and the transition timeline it produces.

use anyhow::Result;

use crate::config::ParallelConfig;
use crate::metrics::ScalingMetrics;

/// What a scaling event does to the serving timeline, all times relative to
/// the scale command (t = 0).
#[derive(Debug, Clone)]
pub struct ScalingOutcome {
    /// Measured latency/downtime/peak-memory (the paper's scaling metrics).
    pub metrics: ScalingMetrics,
    /// When the target instance is ready to serve.
    pub ready_after: f64,
    /// Window with no serving instance (cold restart), if any.
    pub downtime: Option<(f64, f64)>,
    /// Window during which the active instance pauses *new* intake
    /// (ElasticMoE's transition-capacity trade-off, §C).
    pub intake_pause: Option<(f64, f64)>,
    /// Throughput derate of the active instance during the transition
    /// (colocated: two copies share the devices).
    pub transition_derate: f64,
    /// Whether in-flight requests survive the switchover with their KV
    /// (zero-copy reuse) or must restart from scratch.
    pub preserves_inflight: bool,
    /// The configuration after the event.
    pub new_parallel: ParallelConfig,
    /// Total devices occupied at the transition's peak.
    pub peak_devices: usize,
}

/// A scaling strategy: boots an initial configuration and executes scaling
/// events. All five methods drive the same simulated cluster and serve
/// through the same engine.
pub trait ScalingMethod {
    fn name(&self) -> &'static str;

    /// Boot the initial configuration; returns the boot time (seconds).
    fn boot(&mut self, parallel: &ParallelConfig) -> Result<f64>;

    /// Execute a scaling event to `to`.
    fn scale(&mut self, to: &ParallelConfig) -> Result<ScalingOutcome>;

    /// Current configuration.
    fn current(&self) -> Option<&ParallelConfig>;

    /// Steady-state KV-budget factor (< 1.0 for colocated, which must keep
    /// headroom for a second model copy at all times — Table 2's "Before"
    /// column).
    fn steady_kv_factor(&self) -> f64 {
        1.0
    }

    /// Steady-state batch-capacity factor: colocated also halves its
    /// max concurrent sequences so the second copy's activations fit.
    fn steady_batch_factor(&self) -> f64 {
        1.0
    }
}
