//! The scaling-method interface and the transition timeline it produces.
//!
//! A [`ScalingMethod`] executes a scaling event *instantaneously* in
//! simulated terms and returns a [`ScalingOutcome`] describing what the
//! event does to the serving timeline. The serving simulators
//! ([`crate::coordinator::ServingSim`], [`crate::coordinator::FleetSim`])
//! then *enact* that timeline: they keep the old instance stepping, close
//! intake or kill the instance during the declared windows, and perform the
//! engine switchover at `ready_after`. See
//! `docs/architecture/02-scaling-choreography.md` for the full pipeline.

use anyhow::Result;

use crate::chaos::{FaultKind, PlanAudit};
use crate::config::ParallelConfig;
use crate::kvmigrate::{KvHandoff, KvSnapshot};
use crate::metrics::ScalingMetrics;
use crate::tier::TierShift;

/// A scaling event that hit an injected fault mid-plan and aborted.
///
/// Abort is not failure of the serving system: the HMM rolls every
/// applied plan op back ([`crate::hmm::HmmControl::execute_plan`]), the
/// old instance keeps serving, and the simulators — on seeing
/// [`ScalingOutcome::aborted`] — skip the switchover, reopen intake, and
/// resume any suspended sequences on their origin replica. Not a single
/// in-flight request is dropped; the only serving-visible cost is the
/// brief rollback barrier at the end of the (wasted) transition.
#[derive(Debug, Clone, PartialEq)]
pub struct ScaleAbort {
    /// The injected fault that fired.
    pub fault: FaultKind,
    /// The rollback completed: cluster and configuration are back in
    /// their exact pre-command state.
    pub rolled_back: bool,
    /// Human-readable summary (fault, abort point, restored config).
    pub reason: String,
}

/// What a scaling event does to the serving timeline. All times are in
/// seconds **relative to the scale command** (t = 0); the simulator adds
/// the command's absolute issue time.
///
/// The three easily confused fields, from weakest to strongest effect:
///
/// - [`transition_derate`](Self::transition_derate) — the active instance
///   keeps serving *and* admitting, but slower (a capacity tax, e.g. two
///   colocated model copies sharing the same NPUs).
/// - [`intake_pause`](Self::intake_pause) — the active instance keeps
///   serving its in-flight batch at full speed but admits no *new*
///   requests inside the window; arrivals queue in the coordinator and are
///   handed to the successor at switchover. Queueing delay, no lost work.
/// - [`downtime`](Self::downtime) — no serving instance exists inside the
///   window. Nothing is served, and in-flight progress is lost unless
///   [`preserves_inflight`](Self::preserves_inflight) is set.
#[derive(Debug, Clone)]
pub struct ScalingOutcome {
    /// Measured latency/downtime/peak-memory (the paper's scaling metrics,
    /// §7.3), including the per-stage breakdown of Fig 11.
    pub metrics: ScalingMetrics,
    /// When the target instance is ready to serve. At this instant the
    /// simulator builds the successor engine, migrates in-flight and
    /// queued requests to it, and retires the old instance.
    pub ready_after: f64,
    /// Window `(start, end)` with **no serving instance at all** (cold
    /// restart tears down before booting). `None` for every method that
    /// keeps the old instance alive through the transition.
    pub downtime: Option<(f64, f64)>,
    /// Window `(start, end)` during which the active instance pauses
    /// intake of *new* requests while continuing to serve its in-flight
    /// batch. ElasticMoE with zero-copy pauses only for the final
    /// drain+reroute switchover (the window starts at
    /// `ready_after - switchover`, not at 0 — the concurrent HMM/IMM phase
    /// serves normally); without zero-copy the pause spans the whole
    /// transition, which is then also downtime.
    pub intake_pause: Option<(f64, f64)>,
    /// Throughput multiplier (`0 < x <= 1`) applied to the active instance
    /// for the duration of the transition. 1.0 = no slowdown; Colocated
    /// runs at ~0.35 while two model copies share its devices.
    pub transition_derate: f64,
    /// Whether in-flight requests survive the switchover with their KV
    /// intact (zero-copy reuse: decode resumes on the successor) or must
    /// restart from scratch on the new instance. When
    /// [`kv_handoff`](Self::kv_handoff) is present it refines this blanket
    /// verdict per sequence.
    pub preserves_inflight: bool,
    /// Per-sequence KV handoff plan: which in-flight sequences suspend
    /// during the switchover window (their blocks are in flight) and how
    /// each drained sequence is disposed of — remap-adopt, copy-adopt, or
    /// restart. `None` means no plan was drawn (baselines, events issued
    /// without a live snapshot): the simulator falls back to the blanket
    /// `preserves_inflight` behaviour.
    pub kv_handoff: Option<KvHandoff>,
    /// The parallel configuration after the event. For an aborted event
    /// this is the *origin* configuration — the rollback restored it.
    pub new_parallel: ParallelConfig,
    /// Total devices occupied at the transition's peak (Extravagant holds
    /// old + new sets simultaneously).
    pub peak_devices: usize,
    /// Plan-level accounting for the chaos trace invariants (block
    /// conservation, byte budget). Present when the event planned against
    /// a live KV snapshot; `None` for the baselines and snapshot-less
    /// events.
    pub plan_audit: Option<PlanAudit>,
    /// `Some` when the event aborted on an injected fault and rolled
    /// back. The simulators then keep the old engine: intake reopens and
    /// suspended sequences resume at `ready_after` instead of switching
    /// over. `None` for every completed event (the baselines never
    /// abort — their scale paths bypass the HMM's fault hooks).
    pub aborted: Option<ScaleAbort>,
}

impl ScalingOutcome {
    /// Whether `now` falls inside the downtime window of an event issued
    /// at absolute time `started`.
    pub fn in_downtime(&self, started: f64, now: f64) -> bool {
        self.downtime
            .map(|(a, b)| now >= started + a && now < started + b)
            .unwrap_or(false)
    }

    /// Whether intake is open at `now` for an event issued at absolute
    /// time `started` (outside the `intake_pause` window, or no window).
    pub fn intake_open(&self, started: f64, now: f64) -> bool {
        self.intake_pause
            .map(|(a, b)| !(now >= started + a && now < started + b))
            .unwrap_or(true)
    }
}

/// A scaling strategy: boots an initial configuration and executes scaling
/// events. All five methods (ElasticMoE and the four §7.2 baselines) drive
/// the same simulated cluster and serve through the same engine, so their
/// outcomes are directly comparable.
pub trait ScalingMethod {
    /// Display name used in tables and reports.
    fn name(&self) -> &'static str;

    /// Boot the initial configuration; returns the boot time (seconds).
    /// Must be called exactly once before the first [`scale`](Self::scale).
    fn boot(&mut self, parallel: &ParallelConfig) -> Result<f64>;

    /// Execute a scaling event to `to`, mutating the simulated cluster and
    /// returning the transition timeline for the simulator to enact.
    fn scale(&mut self, to: &ParallelConfig) -> Result<ScalingOutcome>;

    /// Execute a scaling event with a snapshot of the live KV state (the
    /// per-sequence block tables at the command instant). Methods that
    /// migrate KV ([`crate::scaling::ElasticMoE`]) plan a per-sequence
    /// handoff from it; the default ignores the snapshot — the baselines'
    /// drain semantics are exactly the legacy path, which keeps the
    /// `repro exp kvmigrate` delta measurable.
    fn scale_with_kv(
        &mut self,
        to: &ParallelConfig,
        kv: &KvSnapshot,
    ) -> Result<ScalingOutcome> {
        let _ = kv;
        self.scale(to)
    }

    /// Current configuration (`None` before boot).
    fn current(&self) -> Option<&ParallelConfig>;

    /// Steady-state KV-budget factor (< 1.0 for colocated, which must keep
    /// headroom for a second model copy at all times — Table 2's "Before"
    /// column).
    fn steady_kv_factor(&self) -> f64 {
        1.0
    }

    /// Steady-state batch-capacity factor: colocated also halves its
    /// max concurrent sequences so the second copy's activations fit.
    fn steady_batch_factor(&self) -> f64 {
        1.0
    }

    /// Predicted max/mean expert token load across the current placement's
    /// devices (1.0 = balanced or unknown). ElasticMoE reports it from the
    /// HMM's popularity stats; it drives redistribution-only scaling
    /// decisions in [`crate::coordinator::FleetPolicy`].
    fn placement_imbalance(&self) -> f64 {
        1.0
    }

    /// Execute a *redistribution-only* scaling event: same device set, new
    /// expert placement (the response to popularity skew rather than load
    /// volume). Returns `Ok(None)` when the method has no load-aware
    /// placement to apply — every baseline, and ElasticMoE before any
    /// routing stats exist.
    fn rebalance(&mut self) -> Result<Option<ScalingOutcome>> {
        Ok(None)
    }

    /// Park the replica to zero devices, keeping its weights warm (host
    /// DRAM for [`crate::scaling::ElasticMoE`] with the tier enabled;
    /// disk-only otherwise). Returns the background teardown/staging
    /// time, or `Ok(None)` when the method cannot park — the default for
    /// every baseline. A parked method serves nothing until
    /// [`unpark`](Self::unpark).
    fn park(&mut self) -> Result<Option<f64>> {
        Ok(None)
    }

    /// Bring a parked replica back to its pre-park configuration.
    /// Returns the boot time the serving simulator must wait out before
    /// routing traffic (DRAM-warm: host restore + h2d + attach + warmup;
    /// disk-cold: a full cold boot), or `Ok(None)` when nothing is
    /// parked / the method cannot park.
    fn unpark(&mut self) -> Result<Option<f64>> {
        Ok(None)
    }

    /// Drain the method's cross-tier journal (weight bytes moving
    /// between HBM, host DRAM, and disk) since the last drain. The
    /// simulators feed these into the run trace as
    /// [`crate::chaos::TraceEvent::TierShift`] events for the
    /// conservation invariant. Default: no tier, empty journal.
    fn drain_tier_shifts(&mut self) -> Vec<TierShift> {
        Vec::new()
    }

    /// Bytes currently staged in host DRAM, as reported by the method's
    /// *allocator* (not its journal — the conservation invariant
    /// cross-checks the two). Default 0.
    fn dram_resident_bytes(&self) -> u64 {
        0
    }

    /// HBM bytes currently allocated across the replica's device set —
    /// a telemetry gauge sampled by the simulators into the
    /// `replica{N}/hbm_used_bytes` series. Default 0 for methods that
    /// don't own a simulated cluster.
    fn hbm_used_bytes(&self) -> u64 {
        0
    }

    /// Peak HBM watermark across the replica's device set (survives
    /// frees). Default 0.
    fn hbm_peak_bytes(&self) -> u64 {
        0
    }
}
