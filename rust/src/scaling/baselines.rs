//! The four baselines of §7.2, implemented on the same simulated cluster
//! and engine as ElasticMoE (mirroring the paper's all-on-vLLM setup):
//!
//! - **Horizontal (Replica)** — full extra replica on fresh devices; no
//!   downtime, coarse quanta, replicated experts.
//! - **Vertical (Cold Restart)** — tear down, reboot bigger; downtime.
//! - **Vertical (Extravagant)** — boot the target on *fresh* devices, then
//!   release the old ones; no downtime, old+new devices held during.
//! - **Vertical (Colocated)** — boot the target on the *same* devices; no
//!   downtime but double-resident weights and a pre-shrunk KV cache.

use std::cell::RefCell;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use crate::config::{ModelConfig, ParallelConfig};
use crate::device::{Cluster, DeviceId, RegionId};
use crate::imm::loader::disk_loader_teardown;
use crate::metrics::ScalingMetrics;

use super::boot::cold_boot;
use super::outcome::{ScalingMethod, ScalingOutcome};

/// State shared by the DiskLoader-based baselines.
struct BaselineState {
    cluster: Rc<RefCell<Cluster>>,
    model: ModelConfig,
    kv_bytes: u64,
    current: Option<(ParallelConfig, Vec<(DeviceId, RegionId)>)>,
    next_proc: u32,
}

impl BaselineState {
    fn new(cluster: Rc<RefCell<Cluster>>, model: ModelConfig, kv_bytes: u64) -> Self {
        BaselineState {
            cluster,
            model,
            kv_bytes,
            current: None,
            next_proc: 1000,
        }
    }

    fn proc(&mut self) -> u32 {
        self.next_proc += 1;
        self.next_proc
    }

    fn boot_on(
        &mut self,
        parallel: &ParallelConfig,
        kv_factor: f64,
    ) -> Result<(Vec<(DeviceId, RegionId)>, f64, crate::imm::BootBreakdown)>
    {
        let kv = (self.kv_bytes as f64 * kv_factor) as u64;
        let proc = self.proc();
        let mut cluster = self.cluster.borrow_mut();
        let (regions, breakdown) =
            cold_boot(&mut cluster, &self.model, parallel, kv, proc)?;
        Ok((regions, breakdown.total(), breakdown))
    }

    fn teardown_current(&mut self) -> Result<()> {
        if let Some((_, regions)) = self.current.take() {
            let mut cluster = self.cluster.borrow_mut();
            disk_loader_teardown(&mut cluster, &regions)?;
        }
        Ok(())
    }

    fn union_and_reset(&self, to: &ParallelConfig) -> Vec<DeviceId> {
        let mut union = to.devices.clone();
        if let Some((from, _)) = &self.current {
            for &d in &from.devices {
                if !union.contains(&d) {
                    union.push(d);
                }
            }
        }
        self.cluster.borrow_mut().reset_peaks(&union);
        union
    }

    fn metrics_for(
        &self,
        name: &'static str,
        to: &ParallelConfig,
        union: &[DeviceId],
    ) -> ScalingMetrics {
        let from_n = self
            .current
            .as_ref()
            .map(|(p, _)| p.n_devices())
            .unwrap_or(0);
        let mut m = ScalingMetrics::new(name, from_n, to.n_devices());
        m.peak_memory = self.cluster.borrow().peak_over(union);
        m.peak_devices = union.len();
        m
    }
}

/// Vertical (Cold Restart).
pub struct ColdRestart(BaselineState);

impl ColdRestart {
    pub fn new(cluster: Rc<RefCell<Cluster>>, model: ModelConfig, kv_bytes: u64) -> Self {
        ColdRestart(BaselineState::new(cluster, model, kv_bytes))
    }
}

impl ScalingMethod for ColdRestart {
    fn name(&self) -> &'static str {
        "Vertical (Cold Restart)"
    }

    fn boot(&mut self, parallel: &ParallelConfig) -> Result<f64> {
        let (regions, t, _) = self.0.boot_on(parallel, 1.0)?;
        self.0.current = Some((parallel.clone(), regions));
        Ok(t)
    }

    fn scale(&mut self, to: &ParallelConfig) -> Result<ScalingOutcome> {
        let union = self.0.union_and_reset(to);
        // Tear down FIRST (that's the whole problem with this method).
        self.0.teardown_current()?;
        let (regions, boot_t, breakdown) = self.0.boot_on(to, 1.0)?;
        let mut metrics = self.0.metrics_for(self.name(), to, &union);
        for (name, t) in breakdown.stages() {
            metrics.stage(name, t);
        }
        self.0.current = Some((to.clone(), regions));
        metrics.from_devices = union.len() - to.n_devices()
            + to.n_devices().min(union.len());
        metrics.peak_memory = self.0.cluster.borrow().peak_over(&union);
        metrics.scale_latency = boot_t;
        metrics.downtime = boot_t;
        Ok(ScalingOutcome {
            metrics,
            ready_after: boot_t,
            downtime: Some((0.0, boot_t)),
            intake_pause: None,
            transition_derate: 1.0,
            preserves_inflight: false,
            kv_handoff: None,
            new_parallel: to.clone(),
            peak_devices: to.n_devices(),
            plan_audit: None,
            aborted: None,
        })
    }

    fn current(&self) -> Option<&ParallelConfig> {
        self.0.current.as_ref().map(|(p, _)| p)
    }
}

/// Vertical (Extravagant): target booted on fresh devices.
pub struct Extravagant(BaselineState);

impl Extravagant {
    pub fn new(cluster: Rc<RefCell<Cluster>>, model: ModelConfig, kv_bytes: u64) -> Self {
        Extravagant(BaselineState::new(cluster, model, kv_bytes))
    }
}

impl ScalingMethod for Extravagant {
    fn name(&self) -> &'static str {
        "Vertical (Extravagant)"
    }

    fn boot(&mut self, parallel: &ParallelConfig) -> Result<f64> {
        let (regions, t, _) = self.0.boot_on(parallel, 1.0)?;
        self.0.current = Some((parallel.clone(), regions));
        Ok(t)
    }

    fn scale(&mut self, to: &ParallelConfig) -> Result<ScalingOutcome> {
        // `to.devices` must be disjoint from the current set.
        if let Some((from, _)) = &self.0.current {
            if to.devices.iter().any(|d| from.devices.contains(d)) {
                bail!(
                    "Extravagant requires fresh devices (old {:?}, new {:?})",
                    from.devices,
                    to.devices
                );
            }
        }
        let union = self.0.union_and_reset(to);
        let from_n = self
            .0
            .current
            .as_ref()
            .map(|(p, _)| p.n_devices())
            .unwrap_or(0);
        // Old serves while the new boots on fresh devices.
        let (regions, boot_t, breakdown) = self.0.boot_on(to, 1.0)?;
        // Switchover, then release the old devices.
        self.0.teardown_current()?;
        self.0.current = Some((to.clone(), regions));
        let mut metrics = self.0.metrics_for(self.name(), to, &union);
        metrics.from_devices = from_n;
        for (name, t) in breakdown.stages() {
            metrics.stage(name, t);
        }
        metrics.scale_latency = boot_t;
        metrics.downtime = 0.0;
        Ok(ScalingOutcome {
            metrics,
            ready_after: boot_t,
            downtime: None,
            intake_pause: None,
            transition_derate: 1.0,
            preserves_inflight: true, // old instance drains in-flight work
            kv_handoff: None,
            new_parallel: to.clone(),
            peak_devices: union.len(),
            plan_audit: None,
            aborted: None,
        })
    }

    fn current(&self) -> Option<&ParallelConfig> {
        self.0.current.as_ref().map(|(p, _)| p)
    }
}

/// Vertical (Colocated / Concurrent): target booted on the same devices.
pub struct Colocated(BaselineState);

impl Colocated {
    pub fn new(cluster: Rc<RefCell<Cluster>>, model: ModelConfig, kv_bytes: u64) -> Self {
        Colocated(BaselineState::new(cluster, model, kv_bytes))
    }

    /// KV shrink factor the colocated instance runs with at all times
    /// (headroom for the second model copy during transitions).
    pub const KV_FACTOR: f64 = 0.45;
}

impl ScalingMethod for Colocated {
    fn name(&self) -> &'static str {
        "Vertical (Colocated)"
    }

    fn boot(&mut self, parallel: &ParallelConfig) -> Result<f64> {
        let (regions, t, _) = self.0.boot_on(parallel, Self::KV_FACTOR)?;
        self.0.current = Some((parallel.clone(), regions));
        Ok(t)
    }

    fn scale(&mut self, to: &ParallelConfig) -> Result<ScalingOutcome> {
        // New devices must be a superset (scale-up) or subset (scale-down)
        // sharing the old devices.
        let from = self
            .0
            .current
            .as_ref()
            .map(|(p, _)| p.clone())
            .context("not booted")?;
        let shares = to.devices.iter().any(|d| from.devices.contains(d));
        if !shares {
            bail!("Colocated requires overlapping device sets");
        }
        let union = self.0.union_and_reset(to);
        // Boot the target with shrunken KV while the old copy is resident:
        // both copies coexist on the shared devices (peak!).
        let (regions, boot_t, breakdown) =
            self.0.boot_on(to, Self::KV_FACTOR)?;
        // Old torn down only after the new one is ready.
        let old = self.0.current.replace((to.clone(), regions));
        if let Some((_, old_regions)) = old {
            let mut cluster = self.0.cluster.borrow_mut();
            disk_loader_teardown(&mut cluster, &old_regions)?;
        }
        let mut metrics = self.0.metrics_for(self.name(), to, &union);
        metrics.from_devices = from.n_devices();
        for (name, t) in breakdown.stages() {
            metrics.stage(name, t);
        }
        metrics.scale_latency = boot_t;
        metrics.downtime = 0.0;
        Ok(ScalingOutcome {
            metrics,
            ready_after: boot_t,
            downtime: None,
            intake_pause: None,
            // Two copies share the devices: the active instance is heavily
            // derated during the transition (Table 2 "During": 0.467 vs
            // 1.338 steady -> ~0.35).
            transition_derate: 0.35,
            preserves_inflight: true,
            kv_handoff: None,
            new_parallel: to.clone(),
            peak_devices: union.len(),
            plan_audit: None,
            aborted: None,
        })
    }

    fn current(&self) -> Option<&ParallelConfig> {
        self.0.current.as_ref().map(|(p, _)| p)
    }

    fn steady_kv_factor(&self) -> f64 {
        Self::KV_FACTOR
    }

    fn steady_batch_factor(&self) -> f64 {
        Self::KV_FACTOR
    }
}

/// Horizontal (Replica): adds a full replica of the current configuration
/// on fresh devices. The aggregate capacity is modelled as doubled DP with
/// *unchanged per-replica EP* (experts replicated, the paper's L4).
pub struct Horizontal {
    state: BaselineState,
    replicas: usize,
    base: Option<ParallelConfig>,
}

impl Horizontal {
    pub fn new(cluster: Rc<RefCell<Cluster>>, model: ModelConfig, kv_bytes: u64) -> Self {
        Horizontal {
            state: BaselineState::new(cluster, model, kv_bytes),
            replicas: 0,
            base: None,
        }
    }

    /// The aggregate layout across replicas (for the cost model).
    pub fn aggregate_parallel(&self) -> Option<ParallelConfig> {
        let base = self.base.as_ref()?;
        let n = base.n_devices() * self.replicas;
        ParallelConfig::with_ep(
            base.dp * self.replicas,
            base.tp,
            base.ep, // experts confined per replica
            (0..n).collect(),
        )
        .ok()
    }
}

impl ScalingMethod for Horizontal {
    fn name(&self) -> &'static str {
        "Horizontal (Replica)"
    }

    fn boot(&mut self, parallel: &ParallelConfig) -> Result<f64> {
        let (regions, t, _) = self.state.boot_on(parallel, 1.0)?;
        self.state.current = Some((parallel.clone(), regions));
        self.base = Some(parallel.clone());
        self.replicas = 1;
        Ok(t)
    }

    fn scale(&mut self, to: &ParallelConfig) -> Result<ScalingOutcome> {
        let base = self.base.clone().context("not booted")?;
        // Horizontal can only add whole replicas: `to` must be a fresh
        // device set the size of the base config.
        if to.n_devices() != base.n_devices() {
            bail!(
                "Horizontal adds whole replicas of {} devices, asked for {}",
                base.n_devices(),
                to.n_devices()
            );
        }
        let union = self.state.union_and_reset(to);
        let from_n = base.n_devices() * self.replicas;
        let (regions, boot_t, breakdown) = self.state.boot_on(to, 1.0)?;
        // Keep both: the old replica keeps serving.
        if let Some((_, old_regions)) = &mut self.state.current {
            old_regions.extend(regions);
        }
        self.replicas += 1;
        let mut metrics = self.state.metrics_for(self.name(), to, &union);
        metrics.from_devices = from_n;
        metrics.to_devices = base.n_devices() * self.replicas;
        for (name, t) in breakdown.stages() {
            metrics.stage(name, t);
        }
        metrics.scale_latency = boot_t;
        metrics.downtime = 0.0;
        let agg = self.aggregate_parallel().context("aggregate")?;
        Ok(ScalingOutcome {
            metrics,
            ready_after: boot_t,
            downtime: None,
            intake_pause: None,
            transition_derate: 1.0,
            preserves_inflight: true,
            kv_handoff: None,
            new_parallel: agg,
            peak_devices: union.len(),
            plan_audit: None,
            aborted: None,
        })
    }

    fn current(&self) -> Option<&ParallelConfig> {
        self.base.as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::dsv2_lite;

    fn cluster(n: usize) -> Rc<RefCell<Cluster>> {
        Rc::new(RefCell::new(Cluster::cloudmatrix(n)))
    }

    fn par(devs: std::ops::Range<usize>) -> ParallelConfig {
        let v: Vec<usize> = devs.collect();
        ParallelConfig::standard(v.len() / 2, 2, v).unwrap()
    }

    const KV: u64 = 8 << 30;

    #[test]
    fn cold_restart_has_downtime_and_low_peak() {
        let c = cluster(6);
        let mut m = ColdRestart::new(c.clone(), dsv2_lite(), KV);
        m.boot(&par(0..4)).unwrap();
        let used_steady = c.borrow().used_over(&[0, 1, 2, 3]);
        let out = m.scale(&par(0..6)).unwrap();
        assert!(out.downtime.is_some());
        assert!(out.ready_after > 30.0, "{}", out.ready_after);
        // Peak never holds two copies.
        assert!(
            out.metrics.peak_memory < used_steady * 2,
            "peak {} vs steady {used_steady}",
            out.metrics.peak_memory
        );
        assert!(!out.preserves_inflight);
    }

    #[test]
    fn extravagant_no_downtime_but_double_devices() {
        let c = cluster(10);
        let mut m = Extravagant::new(c.clone(), dsv2_lite(), KV);
        m.boot(&par(0..4)).unwrap();
        let out = m
            .scale(&ParallelConfig::standard(3, 2, (4..10).collect()).unwrap())
            .unwrap();
        assert!(out.downtime.is_none());
        assert_eq!(out.peak_devices, 10);
        // Overlapping devices rejected.
        let mut m2 = Extravagant::new(cluster(6), dsv2_lite(), KV);
        m2.boot(&par(0..4)).unwrap();
        assert!(m2.scale(&par(0..6)).is_err());
    }

    #[test]
    fn colocated_doubles_peak_on_shared_devices() {
        let c = cluster(6);
        let mut m = Colocated::new(c.clone(), dsv2_lite(), KV);
        m.boot(&par(0..4)).unwrap();
        let steady = c.borrow().used_over(&[0, 1, 2, 3]);
        let out = m.scale(&par(0..6)).unwrap();
        assert!(out.downtime.is_none());
        assert!(
            out.metrics.peak_memory > steady + steady / 2,
            "peak {} should reflect two copies vs steady {steady}",
            out.metrics.peak_memory
        );
        assert!(out.transition_derate < 0.5);
        assert!(m.steady_kv_factor() < 1.0);
    }

    #[test]
    fn horizontal_adds_whole_replicas_with_confined_ep() {
        let c = cluster(8);
        let mut m = Horizontal::new(c, dsv2_lite(), KV);
        m.boot(&par(0..4)).unwrap();
        let out = m
            .scale(&ParallelConfig::standard(2, 2, (4..8).collect()).unwrap())
            .unwrap();
        assert!(out.downtime.is_none());
        let agg = out.new_parallel;
        assert_eq!(agg.n_devices(), 8);
        assert_eq!(agg.ep, 4, "experts confined per replica");
        assert_eq!(agg.dp, 4);
        // Wrong-size replica rejected.
        assert!(m
            .scale(&ParallelConfig::standard(3, 2, (0..6).collect()).unwrap())
            .is_err());
    }

    #[test]
    fn all_baselines_slower_than_elastic() {
        // Fig 7's headline: ElasticMoE ~0.1x the best baseline.
        use crate::hmm::control::{HmmControl, HmmOptions};
        use crate::imm::manager::{ImmOptions, InstanceManager};
        use crate::scaling::ElasticMoE;

        let c = cluster(6);
        let hmm = HmmControl::new(c, dsv2_lite(), HmmOptions::default());
        let imm = InstanceManager::new(
            ImmOptions::default(),
            crate::device::Timings::cloudmatrix(),
        );
        let mut e = ElasticMoE::new(hmm, imm, KV);
        e.boot(&par(0..4)).unwrap();
        let elastic_t = e.scale(&par(0..6)).unwrap().ready_after;

        let c2 = cluster(6);
        let mut cold = ColdRestart::new(c2, dsv2_lite(), KV);
        cold.boot(&par(0..4)).unwrap();
        let cold_t = cold.scale(&par(0..6)).unwrap().ready_after;

        assert!(
            elastic_t < cold_t * 0.2,
            "elastic {elastic_t} vs cold {cold_t}"
        );
    }
}
