//! ElasticMoE's scaling choreography (§5.2, Fig 6): plan -> concurrent
//! {HMM memory reconfiguration ∥ IMM instance preparation} -> zero-copy
//! attach -> warmup -> switchover, with deferred frees at drain.

use anyhow::{Context, Result};

use crate::chaos::PlanAudit;
use crate::config::ParallelConfig;
use crate::hmm::control::{HmmControl, InstanceBinding};
use crate::imm::manager::InstanceManager;
use crate::imm::InstanceState;
use crate::kvmigrate::{KvHandoff, KvHandoffPolicy, KvSnapshot};
use crate::metrics::ScalingMetrics;

use super::outcome::{ScaleAbort, ScalingMethod, ScalingOutcome};

/// The ElasticMoE method: owns the HMM and IMM.
pub struct ElasticMoE {
    pub hmm: HmmControl,
    pub imm: InstanceManager,
    kv_bytes_per_device: u64,
    current: Option<ParallelConfig>,
    active_proc: Option<u32>,
    /// Binding of the most recently activated instance (live path rebinds
    /// its backend from this).
    pub last_binding: Option<InstanceBinding>,
    /// Pre-initialise standby instances for +/- this many device deltas.
    pub anticipate_steps: Vec<isize>,
    /// How live sequences' KV crosses a scaling event: per-sequence
    /// remap/copy/recompute legs (default) or the legacy
    /// drain-and-recompute switchover (the `repro exp kvmigrate`
    /// baseline).
    pub kv_policy: KvHandoffPolicy,
    /// Park keeps weights DRAM-resident (true, the tiered fast path:
    /// unpark pays host-restore + h2d + attach + warmup) or drops them
    /// to disk (false: unpark is a full cold boot — the `repro exp tier`
    /// baseline).
    pub park_warm: bool,
    /// Configuration a parked replica returns to on unpark.
    parked: Option<ParallelConfig>,
}

impl ElasticMoE {
    pub fn new(
        hmm: HmmControl,
        imm: InstanceManager,
        kv_bytes_per_device: u64,
    ) -> Self {
        ElasticMoE {
            hmm,
            imm,
            kv_bytes_per_device,
            current: None,
            active_proc: None,
            last_binding: None,
            // In units of the model's fixed TP (one DP replica per step).
            // Delta 0 keeps a standby of the *current* shape warm so
            // redistribution-only events (same devices, new placement)
            // also skip pre-init.
            anticipate_steps: vec![-1, 1, 2, 4, 0],
            kv_policy: KvHandoffPolicy::default(),
            park_warm: true,
            parked: None,
        }
    }

    /// Pre-initialise standby instances for anticipated neighbour
    /// configurations (runs in the background; free at scale time).
    fn anticipate(&mut self, around: &ParallelConfig) {
        let tp = around.tp;
        let cluster_n = self.hmm.cluster.borrow().len();
        for &delta in &self.anticipate_steps.clone() {
            let n = around.n_devices() as isize + delta * tp as isize;
            if n <= 0 || n as usize > cluster_n {
                continue;
            }
            let n = n as usize;
            if n % tp != 0 {
                continue;
            }
            if let Ok(p) = ParallelConfig::standard(n / tp, tp, (0..n).collect())
            {
                if !self.imm.has_standby(&p) {
                    let proc = self.hmm.alloc_proc();
                    self.imm.prepare_standby(p, proc);
                }
            }
        }
        // The current shape's standby (delta 0, prepared above) is the
        // one redistribution-only events and park/unpark reacquire: pin
        // it so anticipation churn can never evict it mid-activation.
        self.imm.pin_standby(around);
    }
}

impl ElasticMoE {
    /// The shared scaling choreography. `kv` is the live-sequence
    /// snapshot taken at the command instant, when the caller has one;
    /// under [`KvHandoffPolicy::Migrate`] (and zero-copy enabled) the HMM
    /// plans per-sequence KV legs from it and the switchover window
    /// stretches by their copy time, during which those sequences are
    /// suspended. Under [`KvHandoffPolicy::DrainRecompute`] — or without
    /// zero-copy — live KV is dropped and in-flight work re-prefills.
    fn scale_inner(
        &mut self,
        to: &ParallelConfig,
        kv: Option<&KvSnapshot>,
    ) -> Result<ScalingOutcome> {
        let from = self
            .current
            .clone()
            .context("ElasticMoE not booted")?;
        let t = self.hmm.cluster.borrow().timings.clone();
        let mut metrics = ScalingMetrics::new(
            self.name(),
            from.n_devices(),
            to.n_devices(),
        );

        // Validate the target against the physical cluster before touching
        // any state.
        self.hmm.cluster.borrow().validate_ids(&to.devices)?;

        // Peak-memory measurement window over the union device set.
        let union: Vec<usize> = {
            let mut u = from.devices.clone();
            for &d in &to.devices {
                if !u.contains(&d) {
                    u.push(d);
                }
            }
            u
        };
        self.hmm.cluster.borrow_mut().reset_peaks(&union);

        // KV legs are planned only when the handoff can actually happen:
        // zero-copy sharing on and the migrate policy selected.
        let kv = kv.filter(|_| {
            self.kv_policy == KvHandoffPolicy::Migrate
                && self.hmm.opts.use_zero_copy
        });

        // 1) HMM reconfigures memory concurrently with serving.
        let plan = self.hmm.plan_scale_with_kv(to, kv)?;
        let exec = self.hmm.execute_plan(&plan, to)?;
        let stats = exec.stats.clone();

        // Plan-level accounting for the chaos trace invariants (present
        // whenever a live snapshot was planned against).
        let plan_audit = kv.map(|snapshot| PlanAudit {
            snapshot_blocks: snapshot.total_blocks(),
            kv_remapped_blocks: plan.kv_remapped_blocks(),
            kv_copied_blocks: plan.kv_copied_blocks(),
            kv_freed_blocks: plan.kv_freed_blocks(),
            kv_copied_bytes: plan.kv_copied_bytes(),
            migration_budget_bytes: plan.migration_budget_bytes,
            expert_migration_bytes: plan.expert_migration_bytes(),
        });

        // Per-sequence dispositions for the coordinator, read back from
        // the plan's KV legs (rank-survival logic lives in
        // [`KvHandoff::new`], shared with the planner path). Also derived
        // for aborted events: the coordinator must know which sequences
        // it suspended so it can resume exactly those.
        let derive_handoff = |snapshot: &KvSnapshot| {
            use crate::hmm::PlanOp;
            let (mut remap, mut copy, mut recompute) =
                (Vec::new(), Vec::new(), Vec::new());
            for op in &plan.ops {
                match op {
                    PlanOp::KvBlockRemap { request, .. } => {
                        remap.push(*request)
                    }
                    PlanOp::KvBlockCopy { request, .. } => {
                        copy.push(*request)
                    }
                    PlanOp::KvDropRecompute { request, .. } => {
                        recompute.push(*request)
                    }
                    _ => {}
                }
            }
            KvHandoff::new(remap, copy, recompute, &snapshot.from, to)
        };

        if let Some(report) = exec.aborted {
            // The fault fired mid-plan and the HMM already rolled the
            // cluster back to the pre-command state. No successor is
            // prepared — the old instance keeps serving — and the
            // serving-visible cost is the partial concurrent work plus a
            // short reroute-back barrier, during which the handoff plan's
            // suspended sequences resume on their origin replica.
            metrics.stage("hmm_attn_p2p", stats.attn_p2p_time);
            metrics.stage("hmm_expert_migration", stats.expert_p2p_time);
            metrics.stage("hmm_vpage_remap", stats.remap_time);
            if stats.h2d_time > 0.0 {
                metrics.stage("tier_h2d", stats.h2d_time);
            }
            if stats.d2h_time > 0.0 {
                metrics.stage("tier_d2h", stats.d2h_time);
            }
            metrics.stage("kv_init", stats.kv_init_time);
            if stats.kv_migrate_time > 0.0 {
                metrics.stage("kv_handoff", stats.kv_migrate_time);
            }
            metrics.stage("rollback", stats.rollback_time);
            metrics.stage("switchover", t.switchover);
            let ready_after =
                stats.total + stats.kv_migrate_time + t.switchover;
            // Measured placement for the span timeline: the partial
            // concurrent chain (rollback included) runs [0, total], then
            // the KV legs and the reroute-back barrier fill the pause.
            for &(name, s0, s1) in &stats.stage_marks {
                metrics.stage_mark(name, s0, s1);
            }
            if stats.kv_migrate_time > 0.0 {
                metrics.stage_mark(
                    "kv_handoff",
                    stats.total,
                    stats.total + stats.kv_migrate_time,
                );
            }
            metrics.stage_mark(
                "switchover",
                stats.total + stats.kv_migrate_time,
                ready_after,
            );
            metrics.scale_latency = ready_after;
            metrics.downtime = 0.0;
            metrics.peak_memory = self.hmm.cluster.borrow().peak_over(&union);
            metrics.peak_devices = union.len();
            let reason = format!(
                "scale {} -> {} aborted: {}",
                from.label(),
                to.label(),
                report.reason
            );
            return Ok(ScalingOutcome {
                metrics,
                ready_after,
                downtime: None,
                // Brief pause while the rollback's reroute-back barrier
                // restores a consistent admission state.
                intake_pause: Some((stats.total, ready_after)),
                transition_derate: 1.0,
                preserves_inflight: true,
                kv_handoff: kv.map(derive_handoff),
                new_parallel: from.clone(),
                peak_devices: union.len(),
                plan_audit,
                aborted: Some(ScaleAbort {
                    fault: report.fault,
                    rolled_back: report.rolled_back,
                    reason,
                }),
            });
        }

        // 2) IMM prepares the target instance concurrently.
        let proc = self.hmm.alloc_proc();
        let (inst, prep_time) = self.imm.acquire(to, proc);

        // 3) Zero-copy attach once HMM is done.
        let (binding, attach_time) = self.hmm.attach_instance(proc)?;

        // 4) Warmup, then switchover (drain + reroute). Live-KV copy legs
        // run inside the switchover window — their sequences are
        // suspended so the blocks stay byte-stable — stretching it by the
        // fabric time.
        let warmup = t.warmup_for(self.hmm.model.n_layers);
        let switchover = t.switchover + stats.kv_migrate_time;

        let concurrent = stats.total.max(prep_time);
        let ready_after = concurrent + attach_time + warmup + switchover;

        metrics.stage("hmm_attn_p2p", stats.attn_p2p_time);
        metrics.stage("hmm_expert_migration", stats.expert_p2p_time);
        metrics.stage("hmm_vpage_remap", stats.remap_time);
        if stats.h2d_time > 0.0 {
            metrics.stage("tier_h2d", stats.h2d_time);
        }
        if stats.d2h_time > 0.0 {
            metrics.stage("tier_d2h", stats.d2h_time);
        }
        if stats.realloc_time > 0.0 {
            metrics.stage("hmm_realloc(no-vpage)", stats.realloc_time);
        }
        metrics.stage("kv_init", stats.kv_init_time);
        if stats.kv_migrate_time > 0.0 {
            metrics.stage("kv_handoff", stats.kv_migrate_time);
        }
        metrics.stage("imm_prep", prep_time);
        metrics.stage("zero_copy_attach", attach_time);
        metrics.stage("warmup", warmup);
        // The reroute cost alone: the KV copy legs that stretch the
        // window are already reported as the "kv_handoff" stage.
        metrics.stage("switchover", t.switchover);

        // Measured placement for the span timeline: the HMM chain and
        // IMM prep overlap serving from t=0, attach+warmup follow the
        // slower of the two, and only the final window — KV copy legs
        // plus the reroute — sits inside the declared intake pause.
        for &(name, s0, s1) in &stats.stage_marks {
            metrics.stage_mark(name, s0, s1);
        }
        if prep_time > 0.0 {
            metrics.stage_mark("imm_prep", 0.0, prep_time);
        }
        metrics.stage_mark(
            "zero_copy_attach",
            concurrent,
            concurrent + attach_time,
        );
        metrics.stage_mark(
            "warmup",
            concurrent + attach_time,
            concurrent + attach_time + warmup,
        );
        let window_start = ready_after - switchover;
        if stats.kv_migrate_time > 0.0 {
            metrics.stage_mark(
                "kv_handoff",
                window_start,
                window_start + stats.kv_migrate_time,
            );
        }
        metrics.stage_mark(
            "switchover",
            window_start + stats.kv_migrate_time,
            ready_after,
        );

        let kv_handoff = kv.map(derive_handoff);

        // Switchover bookkeeping: drain + retire the old instance, release
        // its references, free orphaned expert pages.
        if let Some(old_id) = self.imm.drain_active()? {
            // In-flight requests finish on the shared KV; then retire.
            let old = self.imm.retire(old_id, true)?;
            debug_assert_eq!(old.state, InstanceState::Retired);
        }
        if let Some(old_proc) = self.active_proc.replace(proc) {
            self.hmm.detach_instance(old_proc)?;
        }
        self.hmm.apply_deferred_frees()?;

        let new_id = self.imm.register_ready(inst, ready_after)?;
        self.imm.activate(new_id)?;

        // Peak memory across the union (watermark survives the frees).
        metrics.peak_memory = self.hmm.cluster.borrow().peak_over(&union);
        metrics.peak_devices = union.len();
        metrics.scale_latency = ready_after;
        let downtime = if self.hmm.opts.use_zero_copy {
            metrics.downtime = 0.0;
            None
        } else {
            // Without zero-copy the KV cannot be shared: the old instance
            // must stop before the new one owns the cache (Table 1 row 5).
            metrics.downtime = ready_after;
            Some((0.0, ready_after))
        };

        self.current = Some(to.clone());
        self.last_binding = Some(binding);
        self.anticipate(to);

        // With zero-copy enabled the old instance keeps serving — and
        // admitting — while the HMM/IMM work runs concurrently beneath it;
        // intake only pauses for the final drain+reroute window (stretched
        // by any live-KV copy legs) so the in-flight KV handover is
        // consistent (§5.2 step 5). Without zero-copy the whole transition
        // is downtime, so intake is closed from the command onward.
        let intake_pause = if self.hmm.opts.use_zero_copy {
            Some((ready_after - switchover, ready_after))
        } else {
            Some((0.0, ready_after))
        };

        // DrainRecompute deliberately discards in-flight KV even though
        // zero-copy could carry it — the measurable baseline.
        let preserves_inflight = self.hmm.opts.use_zero_copy
            && self.kv_policy == KvHandoffPolicy::Migrate;

        Ok(ScalingOutcome {
            metrics,
            ready_after,
            downtime,
            intake_pause,
            transition_derate: 1.0,
            preserves_inflight,
            kv_handoff,
            new_parallel: to.clone(),
            peak_devices: union.len(),
            plan_audit,
            aborted: None,
        })
    }
}

impl ScalingMethod for ElasticMoE {
    fn name(&self) -> &'static str {
        "ElasticMoE"
    }

    fn boot(&mut self, parallel: &ParallelConfig) -> Result<f64> {
        let t = self.hmm.cluster.borrow().timings.clone();
        let load = self.hmm.load_initial(parallel, self.kv_bytes_per_device)?;
        let proc = self.hmm.alloc_proc();
        let (inst, prep) = self.imm.acquire(parallel, proc);
        let (binding, attach) = self.hmm.attach_instance(proc)?;
        let id = self.imm.register_ready(inst, 0.0)?;
        self.imm.activate(id)?;
        self.active_proc = Some(proc);
        self.current = Some(parallel.clone());
        self.last_binding = Some(binding);
        self.anticipate(parallel);
        // First boot is a cold start: container + prep + load + attach +
        // warmup.
        Ok(t.container_start + prep + load + attach
            + t.warmup_for(self.hmm.model.n_layers))
    }

    fn scale(&mut self, to: &ParallelConfig) -> Result<ScalingOutcome> {
        self.scale_inner(to, None)
    }

    fn scale_with_kv(
        &mut self,
        to: &ParallelConfig,
        kv: &KvSnapshot,
    ) -> Result<ScalingOutcome> {
        self.scale_inner(to, Some(kv))
    }

    fn current(&self) -> Option<&ParallelConfig> {
        self.current.as_ref()
    }

    /// Reported only when load-aware placement could act on it: under
    /// MinMove a skewed measurement would make the fleet policy schedule
    /// rebalances this method will always decline.
    fn placement_imbalance(&self) -> f64 {
        if self.hmm.placement.mode != crate::placement::PlacementMode::LoadAware
        {
            return 1.0;
        }
        self.hmm.placement_imbalance()
    }

    /// Redistribution-only event: re-run the scaling choreography toward
    /// the *same* configuration, letting the load-aware solver pick new
    /// expert owners. Zero-copy reuse covers everything except the
    /// migrated experts, so the event costs expert P2P + remap + warmup —
    /// no capacity change, no downtime. Declines (`None`) only when there
    /// is no load-aware placement to apply; *when* to rebalance is the
    /// caller's call ([`crate::coordinator::FleetPolicy`]'s
    /// `rebalance_threshold` in the fleet).
    fn rebalance(&mut self) -> Result<Option<ScalingOutcome>> {
        use crate::placement::PlacementMode;
        let Some(cur) = self.current.clone() else {
            return Ok(None);
        };
        if self.hmm.placement.mode != PlacementMode::LoadAware
            || self.hmm.load_stats().is_none()
        {
            return Ok(None);
        }
        Ok(Some(self.scale(&cur)?))
    }

    /// Park to zero devices. Warm (`park_warm`, default): every weight
    /// unit demotes to host DRAM through the tier store (dedup'd, one
    /// staged copy per tag), the process and comm groups stay alive, and
    /// the current shape's CPU state goes back to the standby cache — so
    /// unpark pays host-restore + h2d + attach + warmup. Cold: the full
    /// teardown, weights drop to disk (dedup history reset: the next
    /// boot really re-reads), and unpark is a cold boot.
    fn park(&mut self) -> Result<Option<f64>> {
        let Some(cur) = self.current.take() else {
            return Ok(None); // not booted (or already parked)
        };
        // Retire the active instance and release its references before
        // touching HBM: park requires refcounts back at the HMM's own.
        if let Some(old_id) = self.imm.drain_active()? {
            // Warm park keeps the instance's CPU state standby (the
            // process survives); cold park loses it with the process.
            self.imm.retire(old_id, self.park_warm)?;
        }
        if let Some(proc) = self.active_proc.take() {
            self.hmm.detach_instance(proc)?;
        }
        let t = if self.park_warm {
            let stats = self.hmm.park_to_host()?;
            stats.d2h_time
        } else {
            self.hmm.apply_deferred_frees()?;
            self.hmm.teardown_all()?;
            // Cold park forfeits the dedup'd-read history: the next boot
            // pays full disk reads again.
            self.hmm.cluster.borrow_mut().disk.reset_dedup();
            0.0
        };
        self.parked = Some(cur);
        Ok(Some(t))
    }

    /// Unpark back to the pre-park configuration. Returns the boot time
    /// the caller must wait out before routing traffic.
    fn unpark(&mut self) -> Result<Option<f64>> {
        let Some(target) = self.parked.take() else {
            return Ok(None);
        };
        if !self.park_warm {
            // Disk-cold restart: the full boot path (container, pre-init
            // or standby, disk load, attach, warmup).
            return Ok(Some(self.boot(&target)?));
        }
        let t = self.hmm.cluster.borrow().timings.clone();
        let load = self
            .hmm
            .unpark_from_host(&target, self.kv_bytes_per_device)?;
        let proc = self.hmm.alloc_proc();
        // The parked process kept its comm groups; its CPU state restores
        // from the standby cache (host_restore on a warm hit, full
        // pre-init only if park churn evicted it).
        let (inst, prep) = self.imm.acquire(&target, proc);
        let prep = if prep == 0.0 { t.host_restore } else { prep };
        let (binding, attach) = self.hmm.attach_instance(proc)?;
        let id = self.imm.register_ready(inst, 0.0)?;
        self.imm.activate(id)?;
        self.active_proc = Some(proc);
        self.current = Some(target.clone());
        self.last_binding = Some(binding);
        self.anticipate(&target);
        Ok(Some(prep + load + attach + t.warmup_for(self.hmm.model.n_layers)))
    }

    fn drain_tier_shifts(&mut self) -> Vec<crate::tier::TierShift> {
        self.hmm.tier.drain_journal()
    }

    fn dram_resident_bytes(&self) -> u64 {
        self.hmm.cluster.borrow().host.used()
    }

    fn hbm_used_bytes(&self) -> u64 {
        match &self.current {
            Some(p) => self.hmm.cluster.borrow().used_over(&p.devices),
            None => 0,
        }
    }

    fn hbm_peak_bytes(&self) -> u64 {
        match &self.current {
            Some(p) => self.hmm.cluster.borrow().peak_over(&p.devices),
            None => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::RefCell;
    use std::rc::Rc;

    use crate::config::model::dsv2_lite;
    use crate::device::{Cluster, Timings};
    use crate::hmm::control::HmmOptions;
    use crate::imm::manager::ImmOptions;

    fn elastic(n: usize) -> ElasticMoE {
        let cluster = Rc::new(RefCell::new(Cluster::cloudmatrix(n)));
        let hmm = HmmControl::new(
            cluster,
            dsv2_lite(),
            HmmOptions::default(),
        );
        let imm = InstanceManager::new(
            ImmOptions::default(),
            Timings::cloudmatrix(),
        );
        ElasticMoE::new(hmm, imm, 8 << 30)
    }

    fn par(n: usize) -> ParallelConfig {
        ParallelConfig::standard(n / 2, 2, (0..n).collect()).unwrap()
    }

    #[test]
    fn scale_up_is_seconds_not_minutes() {
        let mut e = elastic(6);
        let boot = e.boot(&par(4)).unwrap();
        assert!(boot > 30.0, "cold boot should be slow: {boot}");
        let out = e.scale(&par(6)).unwrap();
        // Paper Table 1: ~2.4 s for DP3->DP4; ours must be single-digit
        // seconds with warmup dominating.
        assert!(
            out.ready_after > 1.5 && out.ready_after < 12.0,
            "elastic scale-up {}",
            out.ready_after
        );
        assert!(out.downtime.is_none());
        assert!(out.preserves_inflight);
        assert_eq!(out.metrics.downtime, 0.0);
        // Warmup dominates (Fig 11).
        let warmup = out
            .metrics
            .stages
            .iter()
            .find(|(n, _)| n == "warmup")
            .unwrap()
            .1;
        let others: f64 = out
            .metrics
            .stages
            .iter()
            .filter(|(n, _)| n != "warmup" && n != "imm_prep")
            .map(|(_, t)| t)
            .sum();
        assert!(warmup > others * 0.5, "warmup {warmup} vs others {others}");
    }

    #[test]
    fn standby_hit_skips_preinit() {
        let mut e = elastic(6);
        e.boot(&par(4)).unwrap();
        // boot() anticipated DP3-TP2 (6 devices).
        assert!(e.imm.has_standby(&par(6)));
        let out = e.scale(&par(6)).unwrap();
        let prep = out
            .metrics
            .stages
            .iter()
            .find(|(n, _)| n == "imm_prep")
            .unwrap()
            .1;
        assert_eq!(prep, 0.0, "standby hit must be free");
    }

    #[test]
    fn preinit_disabled_dominates_latency() {
        let mut e = elastic(6);
        e.imm.opts.pre_init = false;
        e.boot(&par(4)).unwrap();
        let out = e.scale(&par(6)).unwrap();
        // Table 1 -PreInit: scale time jumps to ~60 s.
        assert!(
            out.ready_after > 40.0,
            "without preinit: {}",
            out.ready_after
        );
        assert!(out.downtime.is_none(), "still no downtime");
    }

    #[test]
    fn intake_pauses_only_during_switchover() {
        // Regression: with zero-copy concurrent serving, the old instance
        // keeps admitting requests during the HMM/IMM/attach/warmup phase;
        // only the final switchover window closes intake.
        let mut e = elastic(6);
        e.boot(&par(4)).unwrap();
        let out = e.scale(&par(6)).unwrap();
        let switchover = Timings::cloudmatrix().switchover;
        let (a, b) = out.intake_pause.unwrap();
        assert!(
            (b - out.ready_after).abs() < 1e-9,
            "pause ends at readiness: {b} vs {}",
            out.ready_after
        );
        assert!(
            (b - a - switchover).abs() < 1e-9,
            "pause window {} should equal switchover {switchover}",
            b - a
        );
        assert!(
            a > 0.0,
            "intake must stay open during the concurrent phase (a = {a})"
        );
    }

    #[test]
    fn no_zero_copy_pauses_intake_for_whole_transition() {
        let mut e = elastic(6);
        e.hmm.opts.use_zero_copy = false;
        e.hmm.opts.ipc_safe_alloc = false;
        e.boot(&par(4)).unwrap();
        let out = e.scale(&par(6)).unwrap();
        assert_eq!(out.intake_pause, Some((0.0, out.ready_after)));
    }

    #[test]
    fn no_zero_copy_causes_downtime() {
        let mut e = elastic(6);
        e.hmm.opts.use_zero_copy = false;
        e.hmm.opts.ipc_safe_alloc = false;
        e.boot(&par(4)).unwrap();
        let out = e.scale(&par(6)).unwrap();
        assert!(out.downtime.is_some());
        assert!(out.metrics.downtime > 0.0);
        assert!(!out.preserves_inflight);
    }

    #[test]
    fn rebalance_without_load_stats_is_a_noop() {
        let mut e = elastic(4);
        e.boot(&par(4)).unwrap();
        // Default MinMove mode, no stats: nothing to do.
        assert!(e.rebalance().unwrap().is_none());
        assert_eq!(e.placement_imbalance(), 1.0);
    }

    #[test]
    fn rebalance_is_a_fast_zero_downtime_event() {
        let mut e = elastic(4);
        e.hmm.placement = crate::placement::PlacementConfig::load_aware();
        e.boot(&par(4)).unwrap();
        // Hot experts co-located on EP rank 1 (e % 4 == 1 at boot).
        let n = e.hmm.model.n_experts as usize;
        let mut tokens_per_expert = vec![Vec::new(); n];
        for hot in [5usize, 9, 13, 17] {
            tokens_per_expert[hot] = (0..12).collect();
        }
        let routing = crate::engine::moe::Routing {
            n_tokens: 48,
            n_experts: n,
            tokens_per_expert,
        };
        for layer in 0..e.hmm.model.n_layers as usize {
            e.hmm.record_routing(layer, &routing);
        }
        let before = e.placement_imbalance();
        assert!(before > 1.5, "skew must show up: {before}");

        let out = e.rebalance().unwrap().expect("load-aware rebalance");
        assert!(out.downtime.is_none(), "redistribution keeps serving");
        assert!(out.preserves_inflight);
        assert_eq!(out.new_parallel.n_devices(), 4, "same device set");
        // Delta-0 anticipation keeps the current shape standby: the event
        // is in the same seconds class as a vertical step.
        assert!(out.ready_after < 12.0, "{}", out.ready_after);
        let after = e.placement_imbalance();
        assert!(after < before, "imbalance must improve: {before} -> {after}");
    }

    #[test]
    fn scale_up_with_kv_remaps_all_and_keeps_pause_window() {
        use crate::engine::PagedKv;
        use crate::kvmigrate::KvSnapshot;

        let mut e = elastic(6);
        e.boot(&par(4)).unwrap();
        let mut pool = PagedKv::new(100_000, 16);
        for id in 1u64..=6 {
            pool.admit(id, 4000).unwrap();
        }
        let snap = KvSnapshot::capture(&pool, &par(4));
        let out = e.scale_with_kv(&par(6), &snap).unwrap();
        let h = out.kv_handoff.as_ref().expect("migrate policy plans");
        // Scale-up: every device group survives — pure remap, nothing to
        // suspend, no stretch of the switchover window.
        assert_eq!(h.remap.len(), 6);
        assert!(h.copy.is_empty() && h.recompute.is_empty());
        assert!(h.suspend_ids().is_empty());
        let (a, b) = out.intake_pause.unwrap();
        let switchover = Timings::cloudmatrix().switchover;
        // Remap handovers are O(µs)/sequence: the window stays within a
        // millisecond of the plain switchover (no fabric legs).
        assert!(((b - a) - switchover).abs() < 1e-3, "{}", b - a);
        assert!(out.preserves_inflight);
    }

    #[test]
    fn scale_down_with_kv_stretches_switchover_by_copy_time() {
        use crate::engine::PagedKv;
        use crate::kvmigrate::KvSnapshot;

        let mut e = elastic(6);
        e.boot(&par(6)).unwrap();
        let mut pool = PagedKv::new(100_000, 16);
        for id in 0u64..9 {
            pool.admit(id, 6000).unwrap(); // long contexts: copy wins
        }
        let snap = KvSnapshot::capture(&pool, &par(6));
        let out = e.scale_with_kv(&par(4), &snap).unwrap();
        let h = out.kv_handoff.as_ref().unwrap();
        // DP3 -> DP2 on the device prefix: rank 2 (ids ≡ 2 mod 3) moves.
        assert_eq!(h.copy, vec![2, 5, 8]);
        assert_eq!(h.remap.len(), 6);
        assert!(h.recompute.is_empty(), "long contexts never recompute");
        assert_eq!(h.suspend_ids(), &[2, 5, 8]);
        // The pause window = switchover + KV copy time > plain switchover.
        let (a, b) = out.intake_pause.unwrap();
        let switchover = Timings::cloudmatrix().switchover;
        assert!(b - a > switchover, "window {} must stretch", b - a);
        assert!(
            out.metrics
                .stages
                .iter()
                .any(|(n, t)| n == "kv_handoff" && *t > 0.0),
            "kv_handoff stage must be reported"
        );
        assert!(out.downtime.is_none(), "still zero downtime");
    }

    #[test]
    fn drain_recompute_policy_discards_inflight() {
        use crate::engine::PagedKv;
        use crate::kvmigrate::{KvHandoffPolicy, KvSnapshot};

        let mut e = elastic(6);
        e.kv_policy = KvHandoffPolicy::DrainRecompute;
        e.boot(&par(4)).unwrap();
        let mut pool = PagedKv::new(100_000, 16);
        pool.admit(1, 5000).unwrap();
        let snap = KvSnapshot::capture(&pool, &par(4));
        let out = e.scale_with_kv(&par(6), &snap).unwrap();
        assert!(out.kv_handoff.is_none(), "no per-sequence plan");
        assert!(!out.preserves_inflight, "in-flight work restarts");
        assert!(out.downtime.is_none(), "weights still zero-copy");
    }

    #[test]
    fn warm_park_unpark_strictly_beats_disk_cold() {
        // DRAM-warm path.
        let mut warm = elastic(4);
        warm.boot(&par(4)).unwrap();
        let park_t = warm.park().unwrap().expect("booted method parks");
        assert!(park_t > 0.0, "d2h staging is background but nonzero");
        {
            let c = warm.hmm.cluster.borrow();
            assert!(c.host.used() > 0, "weights DRAM-resident while parked");
            for d in 0..4 {
                assert_eq!(c.devices[d].hbm.used(), 0, "HBM fully released");
            }
        }
        assert!(warm.current().is_none());
        assert!(warm.park().unwrap().is_none(), "double park is a no-op");
        let warm_t = warm.unpark().unwrap().expect("parked method unparks");
        assert!(warm.current().is_some());
        assert_eq!(warm.hmm.cluster.borrow().host.used(), 0);
        assert!(warm.unpark().unwrap().is_none(), "double unpark no-op");

        // Disk-cold park baseline on an identical method.
        let mut cold = elastic(4);
        cold.park_warm = false;
        cold.boot(&par(4)).unwrap();
        cold.park().unwrap().expect("cold park works");
        assert_eq!(
            cold.hmm.cluster.borrow().host.used(),
            0,
            "cold park stages nothing"
        );
        let cold_t = cold.unpark().unwrap().expect("cold unpark works");

        // ISSUE acceptance: DRAM-warm unpark strictly faster than disk
        // cold boot on the same config — by a wide margin, not epsilon.
        assert!(
            warm_t * 3.0 < cold_t,
            "warm unpark {warm_t} vs cold {cold_t}"
        );
        // And the unparked replica is live again: a same-shape scaling
        // event runs the full choreography without error.
        let out = warm.scale(&par(4)).unwrap();
        assert_eq!(out.new_parallel.n_devices(), 4);
    }

    #[test]
    fn park_journal_reconciles_with_the_host_allocator() {
        let mut e = elastic(4);
        e.boot(&par(4)).unwrap();
        e.drain_tier_shifts(); // drop any boot-time noise (none expected)
        e.park().unwrap().unwrap();
        let staged = e.dram_resident_bytes();
        assert!(staged > 0);
        let shifts = e.drain_tier_shifts();
        let journalled: u64 = shifts
            .iter()
            .filter(|s| s.to == crate::tier::TierLevel::HostDram)
            .map(|s| s.bytes)
            .sum();
        assert_eq!(journalled, staged, "journal must match the allocator");
        e.unpark().unwrap().unwrap();
        let back: u64 = e
            .drain_tier_shifts()
            .iter()
            .filter(|s| s.from == crate::tier::TierLevel::HostDram)
            .map(|s| s.bytes)
            .sum();
        assert_eq!(back, journalled, "every staged byte promoted back");
        assert_eq!(e.dram_resident_bytes(), 0);
    }

    #[test]
    fn scale_down_works_and_is_fast() {
        let mut e = elastic(6);
        e.boot(&par(6)).unwrap();
        let out = e.scale(&par(4)).unwrap();
        assert!(out.ready_after < 12.0, "{}", out.ready_after);
        assert_eq!(out.new_parallel.n_devices(), 4);
        assert!(out.downtime.is_none());
    }
}
