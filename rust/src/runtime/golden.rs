//! Golden trace loader: the composed-path prefill + decode trace exported by
//! `aot.py`, which the Rust engine must reproduce (integration tests).

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{self, Json};

/// The golden generation trace.
#[derive(Debug, Clone)]
pub struct Golden {
    pub seed: u64,
    pub n_steps: usize,
    /// `[B][P]` padded prompt token ids.
    pub prompt_ids: Vec<Vec<i32>>,
    /// `[B]` valid prompt lengths.
    pub prompt_lens: Vec<i32>,
    /// `[n_steps][B]` greedy tokens (step 0 = argmax of prefill logits).
    pub tokens: Vec<Vec<i32>>,
    /// Full prefill logits for batch row 0 (tolerance check anchor).
    pub prefill_logits_row0: Vec<f32>,
}

impl Golden {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let path = dir.as_ref().join("golden.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}"))?;
        let v = json::parse(&text).context("parsing golden.json")?;

        fn i32_rows(v: &Json) -> Option<Vec<Vec<i32>>> {
            v.as_arr()?
                .iter()
                .map(|row| {
                    row.as_arr()?
                        .iter()
                        .map(|x| x.as_i64().map(|i| i as i32))
                        .collect()
                })
                .collect()
        }

        Ok(Golden {
            seed: v.get("seed").as_u64().context("seed")?,
            n_steps: v.get("n_steps").as_usize().context("n_steps")?,
            prompt_ids: i32_rows(v.get("prompt_ids")).context("prompt_ids")?,
            prompt_lens: v
                .get("prompt_lens")
                .as_arr()
                .context("prompt_lens")?
                .iter()
                .map(|x| x.as_i64().unwrap_or(0) as i32)
                .collect(),
            tokens: i32_rows(v.get("tokens")).context("tokens")?,
            prefill_logits_row0: v
                .get("prefill_logits_row0")
                .f64_vec()
                .context("prefill_logits_row0")?
                .into_iter()
                .map(|x| x as f32)
                .collect(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    #[test]
    fn load_real_golden_if_present() {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("golden.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let g = Golden::load(&dir).unwrap();
        assert_eq!(g.tokens.len(), g.n_steps);
        assert_eq!(g.prompt_ids.len(), g.prompt_lens.len());
        assert!(!g.prefill_logits_row0.is_empty());
    }
}
