//! Host-side tensors: the interchange type between the engine, the HMM's
//! weight storage, and PJRT literals/buffers.

use anyhow::{bail, Context, Result};

/// A shaped host tensor, f32 or i32 (the only dtypes the artifacts use).
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::F32 { shape, data }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::I32 { shape, data }
    }

    pub fn zeros_f32(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        HostTensor::F32 {
            shape,
            data: vec![0.0; n],
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => {
                shape
            }
        }
    }

    pub fn numel(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn byte_len(&self) -> usize {
        self.numel() * 4
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    /// Convert to an XLA literal (copies to XLA-owned memory).
    pub fn literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> =
            self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32 { data, .. } => {
                xla::Literal::vec1(data).reshape(&dims)?
            }
            HostTensor::I32 { data, .. } => {
                xla::Literal::vec1(data).reshape(&dims)?
            }
        };
        Ok(lit)
    }

    /// Upload to a device-resident PJRT buffer (the real-path analogue of a
    /// weight living in HBM).
    pub fn buffer(&self, client: &xla::PjRtClient) -> Result<xla::PjRtBuffer> {
        let b = match self {
            HostTensor::F32 { shape, data } => {
                client.buffer_from_host_buffer(data, shape, None)?
            }
            HostTensor::I32 { shape, data } => {
                client.buffer_from_host_buffer(data, shape, None)?
            }
        };
        Ok(b)
    }

    /// Read back from an XLA literal.
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape().context("literal has no array shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => {
                Ok(HostTensor::f32(dims, lit.to_vec::<f32>()?))
            }
            xla::ElementType::S32 => {
                Ok(HostTensor::i32(dims, lit.to_vec::<i32>()?))
            }
            ty => bail!("unsupported literal dtype {ty:?}"),
        }
    }

    /// Row-major index helper.
    pub fn idx(&self, coords: &[usize]) -> usize {
        let shape = self.shape();
        assert_eq!(coords.len(), shape.len());
        let mut i = 0;
        for (c, s) in coords.iter().zip(shape) {
            debug_assert!(c < s);
            i = i * s + c;
        }
        i
    }

    /// Maximum absolute difference against another f32 tensor.
    pub fn max_abs_diff(&self, other: &HostTensor) -> Result<f32> {
        let a = self.as_f32()?;
        let b = other.as_f32()?;
        if a.len() != b.len() {
            bail!("shape mismatch: {:?} vs {:?}", self.shape(), other.shape());
        }
        Ok(a.iter()
            .zip(b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0, f32::max))
    }

    /// Argmax along the last axis; returns i32 indices shaped `shape[..-1]`.
    pub fn argmax_last(&self) -> Result<HostTensor> {
        let data = self.as_f32()?;
        let shape = self.shape();
        let last = *shape.last().context("scalar tensor")?;
        let rows = self.numel() / last;
        let mut out = Vec::with_capacity(rows);
        for r in 0..rows {
            let row = &data[r * last..(r + 1) * last];
            let mut best = 0usize;
            for (i, v) in row.iter().enumerate() {
                if *v > row[best] {
                    best = i;
                }
            }
            out.push(best as i32);
        }
        Ok(HostTensor::i32(shape[..shape.len() - 1].to_vec(), out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_accounting() {
        let t = HostTensor::zeros_f32(vec![2, 3, 4]);
        assert_eq!(t.numel(), 24);
        assert_eq!(t.byte_len(), 96);
        assert_eq!(t.idx(&[1, 2, 3]), 23);
        assert_eq!(t.idx(&[0, 0, 0]), 0);
    }

    #[test]
    fn argmax() {
        let t = HostTensor::f32(
            vec![2, 3],
            vec![0.1, 0.9, 0.2, 5.0, -1.0, 2.0],
        );
        let am = t.argmax_last().unwrap();
        assert_eq!(am.as_i32().unwrap(), &[1, 0]);
        assert_eq!(am.shape(), &[2]);
    }

    #[test]
    fn diff() {
        let a = HostTensor::f32(vec![2], vec![1.0, 2.0]);
        let b = HostTensor::f32(vec![2], vec![1.5, 2.0]);
        assert_eq!(a.max_abs_diff(&b).unwrap(), 0.5);
    }
}
