//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime (entry-point names, argument/output specs, weight index).

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::{self, Json};

/// Model dimensions recorded by the compile path.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelDims {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub max_seq: usize,
    pub prefill_len: usize,
    pub batch: usize,
    pub param_count: u64,
}

/// One tensor argument or output of an artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One compiled entry point.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub args: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// One exported weight tensor (raw little-endian f32 on disk).
#[derive(Debug, Clone)]
pub struct WeightSpec {
    pub name: String,
    pub file: String,
    pub shape: Vec<usize>,
    pub sha256: String,
}

impl WeightSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
    pub fn byte_len(&self) -> u64 {
        self.numel() as u64 * 4
    }
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelDims,
    pub layer_tensors: Vec<String>,
    pub artifacts: Vec<ArtifactSpec>,
    pub weights: Vec<WeightSpec>,
}

fn tensor_specs(v: &Json) -> Result<Vec<TensorSpec>> {
    v.as_arr()
        .context("expected array of tensor specs")?
        .iter()
        .map(|t| {
            Ok(TensorSpec {
                name: t.get("name").as_str().context("missing name")?.into(),
                dtype: t.get("dtype").as_str().context("missing dtype")?.into(),
                shape: t.get("shape").usize_vec().context("missing shape")?,
            })
        })
        .collect()
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let v = json::parse(&text).context("parsing manifest.json")?;

        let m = v.get("model");
        let get = |k: &str| -> Result<usize> {
            m.get(k).as_usize().with_context(|| format!("model.{k}"))
        };
        let model = ModelDims {
            name: m.get("name").as_str().unwrap_or("unknown").into(),
            vocab: get("vocab")?,
            d_model: get("d_model")?,
            n_layers: get("n_layers")?,
            n_heads: get("n_heads")?,
            head_dim: get("head_dim")?,
            d_ff: get("d_ff")?,
            n_experts: get("n_experts")?,
            top_k: get("top_k")?,
            max_seq: get("max_seq")?,
            prefill_len: get("prefill_len")?,
            batch: get("batch")?,
            param_count: m.get("param_count").as_u64().context("param_count")?,
        };

        let layer_tensors = v
            .get("layer_tensors")
            .as_arr()
            .context("layer_tensors")?
            .iter()
            .map(|s| s.as_str().unwrap_or("").to_string())
            .collect();

        let artifacts = v
            .get("artifacts")
            .as_arr()
            .context("artifacts")?
            .iter()
            .map(|a| {
                Ok(ArtifactSpec {
                    name: a.get("name").as_str().context("name")?.into(),
                    file: a.get("file").as_str().context("file")?.into(),
                    args: tensor_specs(a.get("args"))?,
                    outputs: tensor_specs(a.get("outputs"))?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let weights = v
            .get("weights")
            .as_arr()
            .context("weights")?
            .iter()
            .map(|w| {
                Ok(WeightSpec {
                    name: w.get("name").as_str().context("name")?.into(),
                    file: w.get("file").as_str().context("file")?.into(),
                    shape: w.get("shape").usize_vec().context("shape")?,
                    sha256: w.get("sha256").as_str().unwrap_or("").into(),
                })
            })
            .collect::<Result<Vec<_>>>()?;

        Ok(Manifest {
            dir,
            model,
            layer_tensors,
            artifacts,
            weights,
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .with_context(|| format!("artifact '{name}' not in manifest"))
    }

    pub fn weight(&self, name: &str) -> Result<&WeightSpec> {
        self.weights
            .iter()
            .find(|w| w.name == name)
            .with_context(|| format!("weight '{name}' not in manifest"))
    }

    /// Total parameter bytes (f32) across all weight tensors.
    pub fn total_weight_bytes(&self) -> u64 {
        self.weights.iter().map(|w| w.byte_len()).sum()
    }

    /// Names of the per-expert weight tensors for (layer, expert).
    pub fn expert_weight_names(&self, layer: usize, expert: usize) -> [String; 3] {
        [
            format!("layer{layer}.w1.e{expert}"),
            format!("layer{layer}.w3.e{expert}"),
            format!("layer{layer}.w2.e{expert}"),
        ]
    }

    /// Names of the non-expert (attention/gate/norm) tensors for a layer.
    pub fn attn_weight_names(&self, layer: usize) -> Vec<String> {
        self.layer_tensors
            .iter()
            .filter(|t| !matches!(t.as_str(), "w1" | "w3" | "w2"))
            .map(|t| format!("layer{layer}.{t}"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn parse_real_manifest_if_present() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.model.n_experts >= 2);
        assert_eq!(m.model.d_model, m.model.n_heads * m.model.head_dim);
        assert!(m.artifact("attn_gate_decode").is_ok());
        assert!(m.artifact("nonexistent").is_err());
        let ag = m.artifact("attn_gate_decode").unwrap();
        assert_eq!(ag.args[0].shape, vec![m.model.batch, m.model.d_model]);
        // weights cover the whole parameter count
        let total: usize = m.weights.iter().map(|w| w.numel()).sum();
        assert_eq!(total as u64, m.model.param_count);
        let names = m.expert_weight_names(0, 3);
        assert!(m.weight(&names[0]).is_ok());
        assert_eq!(m.attn_weight_names(0).len(), 7);
    }
}
