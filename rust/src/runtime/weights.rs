//! Raw weight-file loading (the disk side of the HMM's `disk_copy`
//! primitive). Files are little-endian f32, integrity-checked against the
//! manifest's sha256.

use std::path::Path;

use anyhow::{bail, Context, Result};
use sha2::{Digest, Sha256};

use super::manifest::WeightSpec;
use super::tensor::HostTensor;

/// Read one weight tensor from disk, verifying size (and checksum unless
/// `skip_checksum`).
pub fn load_weight(
    dir: &Path,
    spec: &WeightSpec,
    skip_checksum: bool,
) -> Result<HostTensor> {
    let path = dir.join(&spec.file);
    let bytes = std::fs::read(&path)
        .with_context(|| format!("reading weight file {path:?}"))?;
    if bytes.len() != spec.numel() * 4 {
        bail!(
            "weight '{}': expected {} bytes, file has {}",
            spec.name,
            spec.numel() * 4,
            bytes.len()
        );
    }
    if !skip_checksum && !spec.sha256.is_empty() {
        let digest = hex(&Sha256::digest(&bytes));
        if digest != spec.sha256 {
            bail!("weight '{}': sha256 mismatch (corrupt file?)", spec.name);
        }
    }
    let data: Vec<f32> = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok(HostTensor::f32(spec.shape.clone(), data))
}

fn hex(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push_str(&format!("{b:02x}"));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_roundtrip() {
        let dir = std::env::temp_dir().join("elastic_moe_weights_test");
        std::fs::create_dir_all(&dir).unwrap();
        let data: Vec<f32> = (0..6).map(|i| i as f32 * 0.5).collect();
        let bytes: Vec<u8> =
            data.iter().flat_map(|f| f.to_le_bytes()).collect();
        std::fs::write(dir.join("t.bin"), &bytes).unwrap();
        let spec = WeightSpec {
            name: "t".into(),
            file: "t.bin".into(),
            shape: vec![2, 3],
            sha256: hex(&Sha256::digest(&bytes)),
        };
        let t = load_weight(&dir, &spec, false).unwrap();
        assert_eq!(t.as_f32().unwrap(), &data[..]);

        // Corrupt checksum is rejected...
        let bad = WeightSpec {
            sha256: "00".repeat(32),
            ..spec.clone()
        };
        assert!(load_weight(&dir, &bad, false).is_err());
        // ...unless skipped.
        assert!(load_weight(&dir, &bad, true).is_ok());

        // Wrong size is always rejected.
        let wrong = WeightSpec {
            shape: vec![7],
            ..spec
        };
        assert!(load_weight(&dir, &wrong, true).is_err());
    }
}
