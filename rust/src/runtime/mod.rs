//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! and executes them on the request path. Python never runs at serving time.
//!
//! Interchange format is HLO **text** (`HloModuleProto::from_text_file`):
//! jax >= 0.5 emits serialized protos with 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids cleanly.
//!
//! PJRT handles are `Rc`-based (not `Send`): the runtime is single-threaded
//! by design and is owned by the engine that drives it.

pub mod golden;
pub mod manifest;
pub mod pjrt;
pub mod tensor;
pub mod weights;

pub use golden::Golden;
pub use manifest::{ArtifactSpec, Manifest, ModelDims, TensorSpec, WeightSpec};
pub use pjrt::Pjrt;
pub use tensor::HostTensor;
