//! PJRT client wrapper: compiles HLO-text artifacts once, caches the loaded
//! executables, and runs them with literal or device-buffer arguments.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use super::manifest::Manifest;
use super::tensor::HostTensor;

/// The single-threaded PJRT runtime. Owns the CPU client, the manifest and
/// the compiled-executable cache.
pub struct Pjrt {
    client: xla::PjRtClient,
    manifest: Manifest,
    exes: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl Pjrt {
    /// Create a CPU PJRT client over the given artifacts directory.
    pub fn load(manifest: Manifest) -> Result<Self> {
        let client =
            xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        log::info!(
            "PJRT client: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Pjrt {
            client,
            manifest,
            exes: RefCell::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Compile (or fetch from cache) the named artifact.
    pub fn executable(
        &self,
        name: &str,
    ) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.exes.borrow().get(name) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.artifact(name)?;
        let path = self.manifest.dir.join(&spec.file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling artifact '{name}'"))?,
        );
        log::debug!(
            "compiled artifact '{name}' in {:.1} ms",
            t0.elapsed().as_secs_f64() * 1e3
        );
        self.exes.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Eagerly compile every artifact (instance warmup).
    pub fn warmup_all(&self) -> Result<()> {
        let names: Vec<String> = self
            .manifest
            .artifacts
            .iter()
            .map(|a| a.name.clone())
            .collect();
        for n in &names {
            self.executable(n)?;
        }
        Ok(())
    }

    /// Validate provided tensors against the artifact's argument specs.
    fn check_args(&self, name: &str, shapes: &[Vec<usize>]) -> Result<()> {
        let spec = self.manifest.artifact(name)?;
        if shapes.len() != spec.args.len() {
            bail!(
                "artifact '{name}': expected {} args, got {}",
                spec.args.len(),
                shapes.len()
            );
        }
        for (i, (given, want)) in shapes.iter().zip(&spec.args).enumerate() {
            if given != &want.shape {
                bail!(
                    "artifact '{name}' arg {i} ({}): shape {:?} != expected {:?}",
                    want.name,
                    given,
                    want.shape
                );
            }
        }
        Ok(())
    }

    /// Execute with host tensors (copies in/out). Outputs are un-tupled.
    pub fn run(&self, name: &str, args: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let shapes: Vec<Vec<usize>> =
            args.iter().map(|t| t.shape().to_vec()).collect();
        self.check_args(name, &shapes)?;
        let exe = self.executable(name)?;
        let literals: Vec<xla::Literal> = args
            .iter()
            .map(|t| t.literal())
            .collect::<Result<Vec<_>>>()?;
        let out = exe.execute::<xla::Literal>(&literals)?;
        Self::untuple(&out[0][0])
    }

    /// Execute with device-resident buffers (zero host->device copies for
    /// weights that already live "in HBM"). Outputs are un-tupled literals.
    pub fn run_b(
        &self,
        name: &str,
        args: &[&xla::PjRtBuffer],
    ) -> Result<Vec<HostTensor>> {
        let exe = self.executable(name)?;
        let out = exe.execute_b::<&xla::PjRtBuffer>(args)?;
        Self::untuple(&out[0][0])
    }

    /// Upload a host tensor to a device-resident buffer.
    pub fn upload(&self, t: &HostTensor) -> Result<xla::PjRtBuffer> {
        t.buffer(&self.client)
    }

    fn untuple(buf: &xla::PjRtBuffer) -> Result<Vec<HostTensor>> {
        // aot.py lowers with return_tuple=True: the single output buffer is
        // a tuple literal; decompose and convert each element.
        let lit = buf.to_literal_sync()?;
        let parts = lit.to_tuple()?;
        parts
            .iter()
            .map(HostTensor::from_literal)
            .collect::<Result<Vec<_>>>()
    }

    /// Count of compiled (cached) executables.
    pub fn compiled_count(&self) -> usize {
        self.exes.borrow().len()
    }
}
