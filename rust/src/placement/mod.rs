//! Load-aware expert placement & migration planning (beyond the paper).
//!
//! ElasticMoE's §4.6/§5.2 expert redistribution balances experts by
//! *count* (round-robin `e % ep` at boot, minimal-movement count balance
//! on scaling). Real MoE traffic is heavily skewed: a small set of hot
//! experts receives most tokens (Huang et al., *Towards MoE Deployment*,
//! arXiv:2303.06182), so count-balanced placement leaves the *token* load
//! imbalanced and every decode step waits on the hottest EP rank.
//!
//! This subsystem closes that gap in three parts:
//!
//! 1. **Popularity tracking** — [`ExpertLoadStats`]: an EWMA of
//!    tokens-per-step per layer × expert, fed from the engine's
//!    [`crate::engine::moe::Routing`] via
//!    [`crate::hmm::HmmControl::record_routing`].
//! 2. **Placement solver** — [`solver::solve_layer`]: minimises the max
//!    per-device token load under a per-device capacity and a
//!    migration-byte budget, keeping experts on their current owner when
//!    ties allow (zero-copy reuse), with optional hot-expert replication
//!    ([`solver::replicate_hot`]).
//! 3. **Plan integration** — [`crate::hmm::HmmControl::plan_scale`]
//!    consumes solver output when [`PlacementMode::LoadAware`] is active;
//!    [`crate::scaling::ScalingMethod::rebalance`] runs a
//!    *redistribution-only* scaling event (same devices, new placement)
//!    when [`crate::coordinator::FleetPolicy`] sees the imbalance exceed
//!    its `rebalance_threshold` (the single threshold authority); and
//!    [`crate::engine::CostModel`]'s `ep_imbalance` term makes the
//!    resulting balance visible in simulated throughput.
//!
//! `repro exp placement` compares round-robin, load-aware, and
//! load-aware + replication on a Zipf-skewed trace across an EP
//! reconfiguration. See `docs/architecture/03-expert-placement.md`.

pub mod solver;
pub mod stats;

pub use solver::{
    device_loads, imbalance, replicate_hot, solve_layer, LayerPlacement,
    LayerPlacementInput,
};
pub use stats::ExpertLoadStats;

/// How the HMM chooses expert owners when planning a scaling event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementMode {
    /// Count-balanced minimal-movement placement (the paper's default).
    MinMove,
    /// Load-aware placement from EWMA popularity stats; layers with no
    /// observations fall back to [`PlacementMode::MinMove`].
    LoadAware,
}

/// Placement policy knobs, held by [`crate::hmm::HmmControl`].
#[derive(Debug, Clone, Copy)]
pub struct PlacementConfig {
    pub mode: PlacementMode,
    /// Cap on *discretionary* expert-migration bytes per scaling event
    /// (split evenly across layers, leftovers carrying forward). Forced
    /// moves — experts whose owner leaves the device set — are exempt.
    pub migration_budget_bytes: u64,
    /// Extra expert slots per device above `ceil(E / devices)`, giving the
    /// solver room to pack cold experts around hot ones.
    pub capacity_slack: usize,
    /// Prior tokens added to every expert's predicted load so cold experts
    /// still spread across devices.
    pub uniform_prior: f64,
    /// EWMA weight of the newest routing observation.
    pub ewma_alpha: f64,
    /// Under a chaos HBM-pressure fault, demote the coldest experts
    /// (lowest EWMA) to host DRAM and credit their bytes back into the
    /// migration budget, instead of letting the shrunk budget force
    /// live-KV recompute. Off by default: the pre-tier pressure
    /// behaviour (budget fails, movers re-prefill) stays the measurable
    /// baseline for `repro exp chaos`.
    pub demote_on_pressure: bool,
    /// Cap on experts demoted per scaling event.
    pub max_demotions: usize,
}

impl Default for PlacementConfig {
    fn default() -> Self {
        PlacementConfig {
            mode: PlacementMode::MinMove,
            migration_budget_bytes: u64::MAX,
            capacity_slack: 2,
            uniform_prior: 0.25,
            ewma_alpha: 0.2,
            demote_on_pressure: false,
            max_demotions: 8,
        }
    }
}

impl PlacementConfig {
    /// Load-aware placement with the default knobs.
    pub fn load_aware() -> Self {
        PlacementConfig {
            mode: PlacementMode::LoadAware,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_the_papers_placement() {
        let c = PlacementConfig::default();
        assert_eq!(c.mode, PlacementMode::MinMove);
        assert_eq!(c.migration_budget_bytes, u64::MAX);
        assert_eq!(PlacementConfig::load_aware().mode, PlacementMode::LoadAware);
    }
}
