//! Load-aware expert placement solver.
//!
//! Given the current owner map, per-expert predicted token loads, and a
//! target device set, produce an assignment minimising the **maximum
//! per-device token load** subject to a per-device capacity and a
//! migration-byte budget, with tie-breaking that keeps experts on their
//! current owner (zero-copy reuse costs nothing; a migration costs
//! `bytes_per_expert` over the fabric).
//!
//! Algorithm (per layer): keep-home → forced LPT → budgeted local search.
//!
//! 1. Every expert whose current owner survives in the target set stays
//!    put (hottest first under the capacity cap) — the zero-copy-maximal
//!    starting point, mirroring the minimal-movement placement of
//!    [`crate::hmm::HmmControl`].
//! 2. Homeless experts (owner departed, or home full) are placed
//!    longest-processing-time-first onto the least-loaded device.
//! 3. Local search: repeatedly move one expert off the most-loaded device
//!    when that strictly lowers the pairwise max load, preferring the
//!    cheapest such move, until no improving move exists or the
//!    discretionary-migration budget is exhausted. Each applied move
//!    strictly reduces the sorted load vector, so the loop terminates.
//!
//! An optional post-pass ([`replicate_hot`]) grants the hottest experts
//! extra owners; at serving time the router sends each token to the
//! least-loaded replica ([`crate::engine::moe::Routing::tokens_per_device_replicated`]).

use std::cmp::Ordering;
use std::collections::BTreeMap;

use crate::device::DeviceId;

/// One layer's placement problem.
#[derive(Debug, Clone)]
pub struct LayerPlacementInput<'a> {
    /// Target device set, in EP-rank order.
    pub devices: &'a [DeviceId],
    /// Current owner per expert (may name devices outside `devices`).
    pub current: &'a [DeviceId],
    /// Predicted tokens per step per expert.
    pub load: &'a [f64],
    pub bytes_per_expert: u64,
    /// Maximum experts one device may own.
    pub capacity: usize,
    /// Cap on *discretionary* migration bytes — load-balancing moves the
    /// solver chooses to make. Forced moves are exempt (they must happen
    /// regardless of budget): the source device departed, or holds more
    /// experts than `capacity` allows.
    pub budget_bytes: u64,
    /// Prior tokens added to every expert's load, so cold experts still
    /// spread across devices instead of piling on one.
    pub uniform_prior: f64,
}

/// One layer's solved placement.
#[derive(Debug, Clone)]
pub struct LayerPlacement {
    /// New owner per expert; always a member of the input device set.
    pub owner: Vec<DeviceId>,
    /// Bytes moved by choice (load balancing) — counted against the budget.
    pub discretionary_bytes: u64,
    /// Bytes moved out of necessity: the source device left the
    /// configuration or exceeded the capacity cap.
    pub forced_bytes: u64,
    /// Experts whose owner changed.
    pub migrated: usize,
    /// Predicted max/mean device load of the produced assignment.
    pub imbalance: f64,
}

/// Solve one layer's placement. Panics if the devices cannot hold the
/// experts (`capacity * devices < experts`).
pub fn solve_layer(inp: &LayerPlacementInput) -> LayerPlacement {
    let n = inp.current.len();
    assert_eq!(inp.load.len(), n, "load/current length mismatch");
    let d = inp.devices.len();
    assert!(d > 0, "no target devices");
    assert!(
        inp.capacity * d >= n,
        "capacity {} x {d} devices cannot hold {n} experts",
        inp.capacity
    );
    let index: BTreeMap<DeviceId, usize> = inp
        .devices
        .iter()
        .enumerate()
        .map(|(i, &dev)| (dev, i))
        .collect();
    let w: Vec<f64> = inp.load.iter().map(|&l| l + inp.uniform_prior).collect();

    // Experts by descending weight (stable by index for determinism).
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| w[b].total_cmp(&w[a]).then(a.cmp(&b)));

    let mut assign: Vec<usize> = vec![usize::MAX; n];
    let mut count = vec![0usize; d];
    let mut dload = vec![0.0f64; d];

    // 1) Keep-home (hottest first under the capacity cap). Experts that
    //    cannot stay — home departed, or home over the new capacity — are
    //    forced movers: they relocate regardless of budget.
    let mut homeless: Vec<usize> = Vec::new();
    let mut forced = vec![false; n];
    for &e in &order {
        match index.get(&inp.current[e]) {
            Some(&di) if count[di] < inp.capacity => {
                assign[e] = di;
                count[di] += 1;
                dload[di] += w[e];
            }
            _ => {
                forced[e] = true;
                homeless.push(e);
            }
        }
    }

    // 2) Forced LPT: homeless experts to the least-loaded open device.
    for &e in &homeless {
        let di = (0..d)
            .filter(|&i| count[i] < inp.capacity)
            .min_by(|&a, &b| dload[a].total_cmp(&dload[b]).then(a.cmp(&b)))
            .expect("capacity * devices >= experts");
        assign[e] = di;
        count[di] += 1;
        dload[di] += w[e];
    }

    // Budget cost of holding expert `e` on device slot `di`: forced
    // movers are budget-exempt wherever they land.
    let bytes = inp.bytes_per_expert;
    let disc_of = |e: usize, di: usize| -> u64 {
        if forced[e] {
            return 0;
        }
        match index.get(&inp.current[e]) {
            Some(&home) if home == di => 0,
            _ => bytes,
        }
    };
    let mut disc: u64 = (0..n).map(|e| disc_of(e, assign[e])).sum();

    // 3) Budgeted local search off the most-loaded device.
    for _ in 0..(8 * n.max(1)) {
        let src = (0..d)
            .max_by(|&a, &b| dload[a].total_cmp(&dload[b]).then(b.cmp(&a)))
            .unwrap();
        // Best single move: minimise the pairwise max, then the budget
        // cost, then indices (determinism).
        let mut best: Option<(f64, u64, usize, usize)> = None;
        for e in 0..n {
            if assign[e] != src || w[e] <= 0.0 {
                continue;
            }
            for dst in 0..d {
                if dst == src || count[dst] >= inp.capacity {
                    continue;
                }
                let new_dst = dload[dst] + w[e];
                if new_dst >= dload[src] {
                    continue; // must strictly reduce the pair max
                }
                let pair_max = (dload[src] - w[e]).max(new_dst);
                let new_disc = disc - disc_of(e, src) + disc_of(e, dst);
                if new_disc > inp.budget_bytes && new_disc > disc {
                    continue; // over budget and not an improvement
                }
                let better = match best {
                    None => true,
                    Some((bm, bd, be, bdst)) => {
                        match pair_max.total_cmp(&bm) {
                            Ordering::Less => true,
                            Ordering::Greater => false,
                            Ordering::Equal => {
                                (new_disc, e, dst) < (bd, be, bdst)
                            }
                        }
                    }
                };
                if better {
                    best = Some((pair_max, new_disc, e, dst));
                }
            }
        }
        let Some((_, new_disc, e, dst)) = best else { break };
        dload[src] -= w[e];
        count[src] -= 1;
        dload[dst] += w[e];
        count[dst] += 1;
        assign[e] = dst;
        disc = new_disc;
    }

    let owner: Vec<DeviceId> =
        assign.iter().map(|&di| inp.devices[di]).collect();
    let mut forced_bytes = 0u64;
    let mut migrated = 0usize;
    for e in 0..n {
        if owner[e] != inp.current[e] {
            migrated += 1;
            if forced[e] {
                forced_bytes += bytes;
            }
        }
    }
    LayerPlacement {
        owner,
        discretionary_bytes: disc,
        forced_bytes,
        migrated,
        imbalance: imbalance(&dload),
    }
}

/// Hot-expert replication: grant up to `n_replicas` extra owners to the
/// hottest experts, each replica on the least-loaded device not already
/// owning the expert, while it strictly reduces the predicted max
/// per-device load. An expert's load is assumed to split evenly across its
/// owners (the router picks the least-loaded replica at serving time).
/// Returns the owner set per expert (primary first).
pub fn replicate_hot(
    owner: &[DeviceId],
    load: &[f64],
    devices: &[DeviceId],
    n_replicas: usize,
    capacity: usize,
) -> Vec<Vec<DeviceId>> {
    let d = devices.len();
    let index: BTreeMap<DeviceId, usize> = devices
        .iter()
        .enumerate()
        .map(|(i, &dev)| (dev, i))
        .collect();
    let mut owners: Vec<Vec<usize>> = owner
        .iter()
        .map(|dev| vec![*index.get(dev).expect("owner outside device set")])
        .collect();
    let mut count = vec![0usize; d];
    for os in &owners {
        count[os[0]] += 1;
    }

    let loads_of = |owners: &[Vec<usize>]| -> Vec<f64> {
        let mut dl = vec![0.0f64; d];
        for (e, os) in owners.iter().enumerate() {
            let share = load[e] / os.len() as f64;
            for &di in os {
                dl[di] += share;
            }
        }
        dl
    };

    for _ in 0..n_replicas {
        let dl = loads_of(&owners);
        let cur_max = dl.iter().cloned().fold(0.0, f64::max);
        // Hottest per-owner share on the most-loaded device.
        let src = (0..d)
            .max_by(|&a, &b| dl[a].total_cmp(&dl[b]).then(b.cmp(&a)))
            .unwrap();
        let candidate = (0..owner.len())
            .filter(|&e| owners[e].contains(&src))
            .max_by(|&a, &b| {
                let sa = load[a] / owners[a].len() as f64;
                let sb = load[b] / owners[b].len() as f64;
                sa.total_cmp(&sb).then(b.cmp(&a))
            });
        let Some(e) = candidate else { break };
        let dst = (0..d)
            .filter(|&i| !owners[e].contains(&i) && count[i] < capacity)
            .min_by(|&a, &b| dl[a].total_cmp(&dl[b]).then(a.cmp(&b)));
        let Some(dst) = dst else { break };
        // Apply only if the predicted max strictly drops.
        let mut trial = owners.clone();
        trial[e].push(dst);
        let new_max = loads_of(&trial).iter().cloned().fold(0.0, f64::max);
        if new_max >= cur_max {
            break;
        }
        owners = trial;
        count[dst] += 1;
    }

    owners
        .into_iter()
        .map(|os| os.into_iter().map(|di| devices[di]).collect())
        .collect()
}

/// Per-device predicted load of a (possibly replicated) assignment,
/// aligned with `devices`. An expert's load splits evenly across its
/// owners; owners outside `devices` are ignored.
pub fn device_loads(
    owners: &[Vec<DeviceId>],
    load: &[f64],
    devices: &[DeviceId],
) -> Vec<f64> {
    let index: BTreeMap<DeviceId, usize> = devices
        .iter()
        .enumerate()
        .map(|(i, &dev)| (dev, i))
        .collect();
    let mut dl = vec![0.0f64; devices.len()];
    for (e, os) in owners.iter().enumerate() {
        let present: Vec<usize> = os
            .iter()
            .filter_map(|dev| index.get(dev).copied())
            .collect();
        if present.is_empty() {
            continue;
        }
        let share = load[e] / present.len() as f64;
        for di in present {
            dl[di] += share;
        }
    }
    dl
}

/// Max/mean of a load vector (1.0 when empty or all-zero).
pub fn imbalance(loads: &[f64]) -> f64 {
    let total: f64 = loads.iter().sum();
    if loads.is_empty() || total <= 0.0 {
        return 1.0;
    }
    let mean = total / loads.len() as f64;
    let max = loads.iter().cloned().fold(0.0, f64::max);
    (max / mean).max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn single(owner: &[DeviceId]) -> Vec<Vec<DeviceId>> {
        owner.iter().map(|&d| vec![d]).collect()
    }

    fn input<'a>(
        devices: &'a [DeviceId],
        current: &'a [DeviceId],
        load: &'a [f64],
    ) -> LayerPlacementInput<'a> {
        LayerPlacementInput {
            devices,
            current,
            load,
            bytes_per_expert: 100,
            capacity: current.len(), // unconstrained by default
            budget_bytes: u64::MAX,
            uniform_prior: 0.0,
        }
    }

    #[test]
    fn balanced_load_stays_home() {
        let devices = [0, 1];
        let current = [0, 0, 1, 1];
        let load = [5.0, 5.0, 5.0, 5.0];
        let out = solve_layer(&input(&devices, &current, &load));
        assert_eq!(out.owner, current);
        assert_eq!(out.migrated, 0);
        assert_eq!(out.discretionary_bytes, 0);
        assert!((out.imbalance - 1.0).abs() < 1e-12);
    }

    #[test]
    fn skewed_load_is_rebalanced_to_the_optimum() {
        let devices = [0, 1];
        // Device 0 owns the hot expert plus two warm ones; device 1 is cold.
        let current = [0, 0, 0, 1];
        let load = [10.0, 4.0, 4.0, 1.0];
        let out = solve_layer(&input(&devices, &current, &load));
        // Optimal split is 10 vs 9 (hot expert alone or with the light
        // one); the solver must reach it, moving exactly two experts.
        let l0: f64 = (0..4)
            .filter(|&e| out.owner[e] == 0)
            .map(|e| load[e])
            .sum();
        let max = l0.max(19.0 - l0);
        assert_eq!(max, 10.0, "{:?}", out.owner);
        assert_eq!(out.migrated, 2);
        assert_eq!(out.discretionary_bytes, 200);
        let mean = 19.0 / 2.0;
        assert!((out.imbalance - max / mean).abs() < 1e-12);
    }

    #[test]
    fn departed_device_forces_moves_budget_exempt() {
        let devices = [0, 1];
        // Device 9 is leaving; its experts must move even at zero budget.
        let current = [9, 9, 0, 1];
        let load = [3.0, 3.0, 3.0, 3.0];
        let mut inp = input(&devices, &current, &load);
        inp.budget_bytes = 0;
        let out = solve_layer(&inp);
        assert!(out.owner.iter().all(|d| devices.contains(d)));
        assert_eq!(out.forced_bytes, 200);
        assert_eq!(out.discretionary_bytes, 0, "budget must hold");
        // Forced placement is balanced: one homeless expert per device.
        let c0 = out.owner.iter().filter(|&&d| d == 0).count();
        assert_eq!(c0, 2, "{:?}", out.owner);
    }

    #[test]
    fn zero_budget_freezes_discretionary_moves() {
        let devices = [0, 1];
        let current = [0, 0, 0, 1];
        let load = [10.0, 4.0, 4.0, 1.0];
        let mut inp = input(&devices, &current, &load);
        inp.budget_bytes = 0;
        let out = solve_layer(&inp);
        assert_eq!(out.owner, current, "no budget, no moves");
        assert_eq!(out.discretionary_bytes, 0);
    }

    #[test]
    fn partial_budget_spends_on_the_best_move_only() {
        let devices = [0, 1];
        let current = [0, 0, 0, 1];
        let load = [10.0, 4.0, 4.0, 1.0];
        let mut inp = input(&devices, &current, &load);
        inp.budget_bytes = 100; // one move only
        let out = solve_layer(&inp);
        assert_eq!(out.migrated, 1);
        assert_eq!(out.discretionary_bytes, 100);
        // The single best move is the hot expert: 8 vs 11 beats 14 vs 5.
        let l0: f64 = (0..4)
            .filter(|&e| out.owner[e] == 0)
            .map(|e| load[e])
            .sum();
        assert_eq!(l0.max(19.0 - l0), 11.0, "{:?}", out.owner);
    }

    #[test]
    fn capacity_evictions_are_forced_not_budget_blocked() {
        let devices = [0, 1];
        let current = [0, 0, 0, 0];
        let load = [4.0, 3.0, 2.0, 1.0];
        let mut inp = input(&devices, &current, &load);
        inp.capacity = 2;
        inp.budget_bytes = 0;
        let out = solve_layer(&inp);
        // Two experts cannot stay on device 0: they relocate despite the
        // zero budget and are accounted as forced, not discretionary.
        let c0 = out.owner.iter().filter(|&&d| d == 0).count();
        assert_eq!(c0, 2, "{:?}", out.owner);
        assert_eq!(out.discretionary_bytes, 0);
        assert_eq!(out.forced_bytes, 200);
        assert_eq!(out.migrated, 2);
    }

    #[test]
    fn capacity_cap_is_respected() {
        let devices = [0, 1, 2];
        let current = [0, 0, 0, 0, 0, 0];
        let load = [1.0; 6];
        let mut inp = input(&devices, &current, &load);
        inp.capacity = 2;
        inp.uniform_prior = 0.1;
        let out = solve_layer(&inp);
        for d in devices {
            let c = out.owner.iter().filter(|&&o| o == d).count();
            assert!(c <= 2, "device {d} over capacity: {:?}", out.owner);
        }
    }

    #[test]
    fn uniform_prior_spreads_cold_experts_to_new_devices() {
        // All-zero loads (cold stats): the prior still drives count balance,
        // so a scale-up populates the new device.
        let devices = [0, 1];
        let current = [0, 0, 0, 0];
        let load = [0.0; 4];
        let mut inp = input(&devices, &current, &load);
        inp.uniform_prior = 1.0;
        let out = solve_layer(&inp);
        let c1 = out.owner.iter().filter(|&&o| o == 1).count();
        assert_eq!(c1, 2, "{:?}", out.owner);
    }

    #[test]
    fn solver_is_deterministic() {
        let devices = [3, 1, 4];
        let current = [1, 1, 1, 3, 3, 4, 9, 9];
        let load = [8.0, 1.0, 2.5, 7.0, 0.5, 3.0, 6.0, 0.25];
        let a = solve_layer(&input(&devices, &current, &load));
        let b = solve_layer(&input(&devices, &current, &load));
        assert_eq!(a.owner, b.owner);
        assert_eq!(a.discretionary_bytes, b.discretionary_bytes);
    }

    #[test]
    fn replication_splits_the_hottest_expert() {
        let devices = [0, 1, 2];
        let owner = [0, 1, 2];
        let load = [12.0, 2.0, 1.0];
        let owners = replicate_hot(&owner, &load, &devices, 2, 3);
        assert!(owners[0].len() > 1, "hot expert must gain a replica");
        let dl = device_loads(&owners, &load, &devices);
        let max0 =
            device_loads(&single(&owner), &load, &devices)
                .iter()
                .cloned()
                .fold(0.0, f64::max);
        let max1 = dl.iter().cloned().fold(0.0, f64::max);
        assert!(max1 < max0, "replication must cut the peak: {max0} -> {max1}");
    }

    #[test]
    fn replication_stops_when_it_cannot_help() {
        let devices = [0, 1];
        let owner = [0, 1];
        let load = [1.0, 1.0];
        let owners = replicate_hot(&owner, &load, &devices, 4, 2);
        // Balanced already: replicating can't reduce the max.
        assert!(owners.iter().all(|os| os.len() == 1), "{owners:?}");
    }

    #[test]
    fn imbalance_helper_edge_cases() {
        assert_eq!(imbalance(&[]), 1.0);
        assert_eq!(imbalance(&[0.0, 0.0]), 1.0);
        assert_eq!(imbalance(&[2.0, 2.0]), 1.0);
        assert!((imbalance(&[3.0, 1.0]) - 1.5).abs() < 1e-12);
    }
}
