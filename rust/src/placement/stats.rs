//! Expert-popularity tracking: EWMA token loads per layer × expert, fed
//! from the engine's per-step routing decisions
//! ([`crate::engine::moe::Routing`]).
//!
//! Production MoE traffic routes most tokens to a small set of hot experts
//! (Huang et al., *Towards MoE Deployment*, arXiv:2303.06182), and which
//! experts are hot drifts with the workload. The stats here are the
//! placement solver's demand forecast: an exponentially weighted moving
//! average of tokens-per-step per expert, cheap to update on the hot path
//! (one multiply-add per expert per layer per step) and robust to routing
//! noise.

use crate::engine::moe::Routing;

/// EWMA token-load tracker, `[layer][expert] -> predicted tokens/step`.
#[derive(Debug, Clone)]
pub struct ExpertLoadStats {
    n_layers: usize,
    n_experts: usize,
    /// EWMA weight of the newest observation (`0 < alpha <= 1`).
    pub alpha: f64,
    ewma: Vec<Vec<f64>>,
    steps: Vec<u64>,
}

impl ExpertLoadStats {
    pub fn new(n_layers: usize, n_experts: usize, alpha: f64) -> Self {
        assert!(
            alpha > 0.0 && alpha <= 1.0,
            "EWMA alpha must be in (0, 1], got {alpha}"
        );
        ExpertLoadStats {
            n_layers,
            n_experts,
            alpha,
            ewma: vec![vec![0.0; n_experts]; n_layers],
            steps: vec![0; n_layers],
        }
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    pub fn n_experts(&self) -> usize {
        self.n_experts
    }

    /// Fold one step's routing decision for `layer` into the EWMA.
    pub fn observe(&mut self, layer: usize, routing: &Routing) {
        assert_eq!(
            routing.n_experts, self.n_experts,
            "routing expert count mismatch"
        );
        let counts: Vec<f64> = routing
            .tokens_per_expert
            .iter()
            .map(|t| t.len() as f64)
            .collect();
        self.observe_counts(layer, &counts);
    }

    /// Fold raw per-expert token counts for one step of `layer`. The first
    /// observation seeds the EWMA directly (no zero-bias warm-up).
    pub fn observe_counts(&mut self, layer: usize, counts: &[f64]) {
        assert_eq!(counts.len(), self.n_experts, "expert count mismatch");
        let row = &mut self.ewma[layer];
        if self.steps[layer] == 0 {
            row.copy_from_slice(counts);
        } else {
            for (v, &c) in row.iter_mut().zip(counts) {
                *v = (1.0 - self.alpha) * *v + self.alpha * c;
            }
        }
        self.steps[layer] += 1;
    }

    /// Predicted tokens-per-step per expert for `layer`.
    pub fn predicted(&self, layer: usize) -> &[f64] {
        &self.ewma[layer]
    }

    /// Observations folded in for `layer`.
    pub fn steps(&self, layer: usize) -> u64 {
        self.steps[layer]
    }

    /// Whether every layer has at least `min_steps` observations.
    pub fn warm(&self, min_steps: u64) -> bool {
        self.steps.iter().all(|&s| s >= min_steps)
    }

    /// Multiply every EWMA entry by `factor` (idle decay between windows,
    /// so stale popularity fades when traffic stops).
    pub fn decay(&mut self, factor: f64) {
        assert!((0.0..=1.0).contains(&factor), "decay factor in [0, 1]");
        for row in &mut self.ewma {
            for v in row.iter_mut() {
                *v *= factor;
            }
        }
    }

    /// Copy of the full `[layer][expert]` load matrix.
    pub fn snapshot(&self) -> Vec<Vec<f64>> {
        self.ewma.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn routing(counts: &[usize]) -> Routing {
        let n_tokens = counts.iter().sum();
        Routing {
            n_tokens,
            n_experts: counts.len(),
            tokens_per_expert: counts
                .iter()
                .map(|&c| (0..c).collect())
                .collect(),
        }
    }

    #[test]
    fn first_observation_seeds_directly() {
        let mut s = ExpertLoadStats::new(2, 3, 0.5);
        s.observe(0, &routing(&[4, 0, 2]));
        assert_eq!(s.predicted(0), &[4.0, 0.0, 2.0]);
        assert_eq!(s.predicted(1), &[0.0, 0.0, 0.0]);
        assert_eq!(s.steps(0), 1);
        assert_eq!(s.steps(1), 0);
        assert!(!s.warm(1));
    }

    #[test]
    fn ewma_converges_toward_steady_counts() {
        let mut s = ExpertLoadStats::new(1, 2, 0.2);
        for _ in 0..100 {
            s.observe_counts(0, &[10.0, 2.0]);
        }
        let p = s.predicted(0);
        assert!((p[0] - 10.0).abs() < 1e-6, "{p:?}");
        assert!((p[1] - 2.0).abs() < 1e-6, "{p:?}");
    }

    #[test]
    fn ewma_tracks_popularity_drift() {
        let mut s = ExpertLoadStats::new(1, 2, 0.3);
        for _ in 0..50 {
            s.observe_counts(0, &[10.0, 0.0]);
        }
        for _ in 0..10 {
            s.observe_counts(0, &[0.0, 10.0]);
        }
        let p = s.predicted(0);
        assert!(p[1] > p[0], "drifted load must dominate: {p:?}");
    }

    #[test]
    fn decay_fades_stale_popularity() {
        let mut s = ExpertLoadStats::new(1, 2, 0.5);
        s.observe_counts(0, &[8.0, 4.0]);
        s.decay(0.5);
        assert_eq!(s.predicted(0), &[4.0, 2.0]);
    }

    #[test]
    #[should_panic(expected = "alpha")]
    fn zero_alpha_rejected() {
        ExpertLoadStats::new(1, 1, 0.0);
    }

}
