//! `cargo bench --bench paper_figures` — regenerates every figure of the
//! paper's evaluation (Figs 1, 4, 7-12) and times each regeneration.
//! Set `BENCH_FAST=1` for a quick pass (fewer models / RPS points).

use elastic_moe::experiments;
use elastic_moe::util::bench::time_fn;

fn main() {
    let fast = std::env::var("BENCH_FAST").is_ok();
    println!("== paper figures (fast={fast}) ==\n");
    let figs = [
        "fig1a", "fig1b", "fig4a", "fig4b", "fig7", "fig8", "fig9a",
        "fig9b", "fig10", "fig11", "fig12",
    ];
    for id in figs {
        let (t, report) = time_fn(|| experiments::run(id, fast));
        match report {
            Ok(r) => {
                println!("{r}");
                println!("[{id} regenerated in {t:.2}s]\n");
            }
            Err(e) => {
                eprintln!("{id} FAILED: {e:#}");
                std::process::exit(1);
            }
        }
    }
}
