//! `cargo bench --bench paper_tables` — regenerates the paper's Tables 1-3
//! (ablations + during-scaling throughput) and times each regeneration.
//! Set `BENCH_FAST=1` for a quick pass.

use elastic_moe::experiments;
use elastic_moe::util::bench::time_fn;

fn main() {
    let fast = std::env::var("BENCH_FAST").is_ok();
    println!("== paper tables (fast={fast}) ==\n");
    for id in ["table1", "table2", "table3"] {
        let (t, report) = time_fn(|| experiments::run(id, fast));
        match report {
            Ok(r) => {
                println!("{r}");
                println!("[{id} regenerated in {t:.2}s]\n");
            }
            Err(e) => {
                eprintln!("{id} FAILED: {e:#}");
                std::process::exit(1);
            }
        }
    }
}
