//! `cargo bench --bench hotpath` — L3 hot-path microbenchmarks for the
//! performance pass (EXPERIMENTS.md §Perf): the serving step loop, KV
//! paging, scaling-plan computation, vpage remaps, the event queue, and the
//! live PJRT decode step (when artifacts are built).

use std::cell::RefCell;
use std::rc::Rc;

use elastic_moe::config::model::{dsv2_lite, e2e};
use elastic_moe::config::ParallelConfig;
use elastic_moe::device::{Cluster, Timings};
use elastic_moe::engine::{
    BatcherConfig, CostModel, CostModelBackend, PagedKv, ServeEngine,
};
use elastic_moe::hmm::control::{HmmControl, HmmOptions};
use elastic_moe::sim::{EventQueue, SimClock};
use elastic_moe::util::bench::Bench;
use elastic_moe::workload::Request;

fn par(n: usize) -> ParallelConfig {
    ParallelConfig::standard(n / 2, 2, (0..n).collect()).unwrap()
}

fn bench_engine_steps(b: &Bench) {
    let backend = CostModelBackend::new(
        CostModel::new(dsv2_lite(), Timings::cloudmatrix()),
        par(4),
    );
    let mut engine = ServeEngine::new(
        BatcherConfig {
            max_batch: 256,
            max_prefill_tokens: 16384,
        },
        PagedKv::new(200_000, 16),
        Box::new(backend),
    );
    let clock = SimClock::new();
    for i in 0..256u64 {
        engine.submit(Request::new(i, 0.0, 2000, 1_000_000));
    }
    // Fill the batch.
    while engine.batcher.running_len() < 256 {
        engine.step(&clock).unwrap();
    }
    let r = b.run("engine decode step (batch=256, sim backend)", || {
        engine.step(&clock).unwrap();
    });
    println!(
        "  -> {:.0} scheduled tokens/sec of coordinator overhead budget",
        r.throughput(256.0)
    );
}

fn bench_kv_paging(b: &Bench) {
    let mut kv = PagedKv::new(1_000_000, 16);
    let mut id = 0u64;
    b.run("paged KV admit+grow+release (2600-token seq)", || {
        id += 1;
        kv.admit(id, 2000).unwrap();
        for _ in 0..600 {
            kv.append_token(id).unwrap();
        }
        kv.release(id);
    });
}

fn bench_scaling_plan(b: &Bench) {
    let cluster = Rc::new(RefCell::new(Cluster::cloudmatrix(8)));
    let mut hmm = HmmControl::new(
        cluster,
        dsv2_lite(),
        HmmOptions::default(),
    );
    hmm.load_initial(&par(6), 8 << 30).unwrap();
    b.run("HMM scale plan computation 6->8 (dsv2lite, 27x64 experts)", || {
        let plan = hmm.plan_scale(&par(8)).unwrap();
        std::hint::black_box(plan.migrated_expert_count());
    });
}

fn bench_vpage_remap(b: &Bench) {
    use elastic_moe::hmm::VpageTable;
    b.run("vpage bind+unbind 27x64 experts", || {
        let mut t = VpageTable::new();
        for l in 0..27 {
            for e in 0..64 {
                t.bind(l, e, (l * 64 + e) as u64).unwrap();
            }
        }
        for l in 0..27 {
            for e in 0..64 {
                t.unbind(l, e).unwrap();
            }
        }
    });
}

fn bench_event_queue(b: &Bench) {
    b.run("event queue push+pop 10k", || {
        let mut q = EventQueue::new();
        for i in 0..10_000u64 {
            q.push((i % 97) as f64, i);
        }
        while q.pop().is_some() {}
    });
}

fn bench_core_comparison() {
    // The `repro bench --json` comparison, surfaced here too so `cargo
    // bench --bench hotpath` shows the event core against the retained
    // windowed reference without a CLI round-trip.
    let cmp = elastic_moe::coordinator::compare_cores(true).unwrap();
    println!(
        "event core vs windowed reference (sparse trace, dt={}s):",
        cmp.dt
    );
    println!(
        "  event core  {:>12.0} events/sec  ({} iterations)",
        cmp.event_events_per_sec(),
        cmp.event.iterations
    );
    println!(
        "  windowed    {:>12.0} events/sec  ({} iterations)",
        cmp.windowed_events_per_sec(),
        cmp.windowed.iterations
    );
    println!(
        "  -> {:.2}x speedup, outputs match: {}",
        cmp.speedup(),
        cmp.outputs_match()
    );
}

fn bench_telemetry_overhead() {
    // Telemetry must stay within the <5% events/sec budget
    // (docs/architecture/08-observability.md): identical runs with the
    // registry off and on — the event sets match, so the wall ratio is
    // the events/sec ratio.
    let o = elastic_moe::coordinator::telemetry_overhead(true).unwrap();
    println!("telemetry overhead (same run, registry off vs on):");
    println!(
        "  off {:.3}s  on {:.3}s  -> {:+.2}% wall, neutral: {}",
        o.off_wall_s,
        o.on_wall_s,
        100.0 * o.overhead_frac(),
        o.neutral()
    );
}

fn bench_pjrt_decode(b: &Bench) {
    use elastic_moe::runtime::{Manifest, Pjrt};
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("  (skipping PJRT decode bench: artifacts not built)");
        return;
    }
    let manifest = Manifest::load(dir).unwrap();
    let rt = Pjrt::load(manifest.clone()).unwrap();
    // Monolithic decode step with the Pallas MoE kernel on the hot path.
    let md = &manifest.model;
    let (bsz, s, h, dh) = (md.batch, md.max_seq, md.n_heads, md.head_dim);
    use elastic_moe::runtime::{weights, HostTensor};
    let mut args: Vec<HostTensor> = vec![
        HostTensor::i32(vec![bsz], vec![1; bsz]),
        HostTensor::i32(vec![bsz], vec![64; bsz]),
    ];
    for _ in 0..2 * md.n_layers {
        args.push(HostTensor::zeros_f32(vec![bsz, s, h, dh]));
    }
    for w in ["emb", "ln_f"] {
        args.push(
            weights::load_weight(&manifest.dir, manifest.weight(w).unwrap(), true)
                .unwrap(),
        );
    }
    for li in 0..md.n_layers {
        for t in manifest.layer_tensors.clone() {
            if matches!(t.as_str(), "w1" | "w3" | "w2") {
                let mut stacked = Vec::new();
                let mut shape = Vec::new();
                for eidx in 0..md.n_experts {
                    let spec = manifest
                        .weight(&format!("layer{li}.{t}.e{eidx}"))
                        .unwrap();
                    let w =
                        weights::load_weight(&manifest.dir, spec, true).unwrap();
                    if shape.is_empty() {
                        shape = vec![md.n_experts];
                        shape.extend_from_slice(w.shape());
                    }
                    stacked.extend_from_slice(w.as_f32().unwrap());
                }
                args.push(HostTensor::f32(shape, stacked));
            } else {
                let spec = manifest.weight(&format!("layer{li}.{t}")).unwrap();
                args.push(
                    weights::load_weight(&manifest.dir, spec, true).unwrap(),
                );
            }
        }
    }
    let r = b.run(
        "PJRT monolithic decode step (e2e model, Pallas MoE kernel)",
        || {
            let out = rt.run("decode_step_full", &args).unwrap();
            std::hint::black_box(out.len());
        },
    );
    let m = e2e();
    let flops = m.flops_per_token() * bsz as f64;
    println!(
        "  -> {:.2} GFLOP/s effective ({} tokens/step)",
        flops / r.mean_s / 1e9,
        bsz
    );
}

fn main() {
    let b = Bench::from_env(3, 30);
    println!("== L3 hot-path microbenchmarks ==");
    bench_engine_steps(&b);
    bench_kv_paging(&b);
    bench_scaling_plan(&b);
    bench_vpage_remap(&b);
    bench_event_queue(&b);
    bench_core_comparison();
    bench_telemetry_overhead();
    let b_slow = Bench::from_env(2, 10);
    bench_pjrt_decode(&b_slow);
}
