//! End-to-end integration: the full Rust stack (HMM weight placement ->
//! zero-copy instance binding -> PJRT backend -> continuous-batching
//! engine) must reproduce the golden generation trace emitted by the
//! JAX compile path, and must keep producing identical tokens after a live
//! expert migration.

use std::cell::RefCell;
use std::path::PathBuf;
use std::rc::Rc;

use elastic_moe::config::{model, ParallelConfig};
use elastic_moe::device::Cluster;
use elastic_moe::engine::pjrt::PjrtBackend;
use elastic_moe::engine::{BatcherConfig, PagedKv, ServeEngine};
use elastic_moe::hmm::control::{HmmControl, HmmOptions, PayloadLoader};
use elastic_moe::hmm::weights::UnitKind;
use elastic_moe::runtime::{weights, Golden, HostTensor, Manifest, Pjrt};
use elastic_moe::sim::RealClock;
use elastic_moe::workload::Request;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Payload loader reading the exported weight files per unit.
fn make_loader(manifest: Manifest) -> PayloadLoader {
    Box::new(move |unit, _tp_rank| {
        let names: Vec<String> = match unit.kind {
            UnitKind::Embed => vec!["emb".into(), "ln_f".into()],
            UnitKind::Attn { layer } => {
                ["ln1", "wq", "wk", "wv", "wo", "ln2", "w_gate"]
                    .iter()
                    .map(|t| format!("layer{layer}.{t}"))
                    .collect()
            }
            UnitKind::Expert { layer, expert } => {
                vec![
                    format!("layer{layer}.w1.e{expert}"),
                    format!("layer{layer}.w3.e{expert}"),
                    format!("layer{layer}.w2.e{expert}"),
                ]
            }
            UnitKind::SharedExpert { .. } => return None,
        };
        let tensors: Option<Vec<HostTensor>> = names
            .iter()
            .map(|n| {
                manifest
                    .weight(n)
                    .ok()
                    .and_then(|spec| {
                        weights::load_weight(&manifest.dir, spec, true).ok()
                    })
            })
            .collect();
        tensors.map(Rc::new)
    })
}

struct Stack {
    hmm: Rc<RefCell<HmmControl>>,
    rt: Rc<Pjrt>,
    golden: Golden,
}

/// `n_devices` in the cluster; the initial instance spans the first `dp`
/// devices (TP=1 for the e2e model).
fn build_stack(n_devices: usize, dp: usize) -> Option<(Stack, ServeEngine)> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let golden = Golden::load(&dir).unwrap();
    let rt = Rc::new(Pjrt::load(manifest.clone()).unwrap());

    let cluster = Rc::new(RefCell::new(Cluster::cloudmatrix(n_devices)));
    let mut hmm = HmmControl::new(cluster, model::e2e(), HmmOptions::default());
    hmm.set_loader(make_loader(manifest.clone()));
    let parallel =
        ParallelConfig::standard(dp, 1, (0..dp).collect()).unwrap();
    hmm.load_initial(&parallel, 64 << 20).unwrap();
    let proc = hmm.alloc_proc();
    let (binding, _t) = hmm.attach_instance(proc).unwrap();
    let hmm = Rc::new(RefCell::new(hmm));

    let backend =
        PjrtBackend::new(rt.clone(), hmm.clone(), binding).unwrap();
    let engine = ServeEngine::new(
        BatcherConfig {
            max_batch: manifest.model.batch,
            max_prefill_tokens: manifest.model.batch * manifest.model.prefill_len,
        },
        PagedKv::new(4096, 16),
        Box::new(backend),
    );
    Some((Stack { hmm, rt, golden }, engine))
}

fn golden_requests(g: &Golden) -> Vec<Request> {
    g.prompt_ids
        .iter()
        .zip(&g.prompt_lens)
        .enumerate()
        .map(|(i, (ids, &len))| {
            let mut r =
                Request::new(i as u64 + 1, 0.0, len as usize, g.n_steps);
            r.prompt_ids = ids[..len as usize].to_vec();
            r
        })
        .collect()
}

fn run_to_completion(engine: &mut ServeEngine) -> Vec<Request> {
    let clock = RealClock::new();
    let mut finished = Vec::new();
    for _ in 0..1000 {
        let out = engine.step(&clock).unwrap();
        finished.extend(out.finished);
        if !engine.has_work() {
            break;
        }
    }
    finished.sort_by_key(|r| r.id);
    finished
}

#[test]
fn engine_reproduces_golden_trace() {
    let Some((stack, mut engine)) = build_stack(2, 2) else { return };
    for r in golden_requests(&stack.golden) {
        engine.submit(r);
    }
    let finished = run_to_completion(&mut engine);
    assert_eq!(finished.len(), stack.golden.prompt_ids.len());
    for (b, r) in finished.iter().enumerate() {
        let expected: Vec<i32> = (0..stack.golden.n_steps)
            .map(|s| stack.golden.tokens[s][b])
            .collect();
        assert_eq!(
            r.output_ids, expected,
            "token mismatch for batch row {b}"
        );
    }
}

#[test]
fn expert_migration_preserves_numerics() {
    // Generate on 2 devices, then scale to 3 (experts migrate) and verify a
    // fresh engine on the new layout produces the identical golden trace —
    // i.e. migrated expert bytes are bit-identical.
    let Some((stack, mut engine)) = build_stack(3, 2) else { return };
    // Note: cluster has 3 devices but the initial config uses 2.
    {
        // Re-init on devices 0..2 only.
        let mut hmm = stack.hmm.borrow_mut();
        let cur = hmm.current_parallel().unwrap().clone();
        assert_eq!(cur.n_devices(), 2);
    }
    // First run on the initial layout.
    for r in golden_requests(&stack.golden) {
        engine.submit(r);
    }
    let before = run_to_completion(&mut engine);

    // Scale 2 -> 3 devices (DP3-TP1-EP3): experts migrate to device 2.
    let to = ParallelConfig::standard(3, 1, vec![0, 1, 2]).unwrap();
    let (plan, stats) = {
        let mut hmm = stack.hmm.borrow_mut();
        let plan = hmm.plan_scale(&to).unwrap();
        let stats = hmm.execute_plan(&plan, &to).unwrap().stats;
        (plan, stats)
    };
    assert!(plan.migrated_expert_count() > 0, "scaling must move experts");
    assert!(stats.total > 0.0);

    // Fresh instance on the new layout.
    let (binding, proc) = {
        let mut hmm = stack.hmm.borrow_mut();
        let proc = hmm.alloc_proc();
        let (b, _) = hmm.attach_instance(proc).unwrap();
        (b, proc)
    };
    assert_eq!(binding.parallel.n_devices(), 3);
    let backend =
        PjrtBackend::new(stack.rt.clone(), stack.hmm.clone(), binding)
            .unwrap();
    let md = stack.rt.manifest().model.clone();
    let mut engine2 = ServeEngine::new(
        BatcherConfig {
            max_batch: md.batch,
            max_prefill_tokens: md.batch * md.prefill_len,
        },
        PagedKv::new(4096, 16),
        Box::new(backend),
    );
    for r in golden_requests(&stack.golden) {
        engine2.submit(r);
    }
    let after = run_to_completion(&mut engine2);

    for (a, b) in before.iter().zip(&after) {
        assert_eq!(
            a.output_ids, b.output_ids,
            "migration changed numerics for request {}",
            a.id
        );
    }
    // Cleanup deferred pages.
    let _ = proc;
    stack.hmm.borrow_mut().apply_deferred_frees().unwrap();
}

/// Regression for the KV-handoff choreography: ElasticMoE's intake-pause
/// window and the per-sequence suspend window compose. Across a
/// scale-down (which suspends the departing replica's sequences for
/// their block copies, while intake is paused for the stretched
/// switchover window), no request is both drained-restarted and
/// migrated — every request finishes exactly once — and token counts
/// are conserved: each finished request produced exactly its requested
/// tokens, with adopted sequences keeping their pre-event progress.
#[test]
fn intake_pause_and_suspend_window_compose() {
    use std::collections::{HashMap, HashSet};

    use elastic_moe::config::SloConfig;
    use elastic_moe::coordinator::{ServingSim, Trigger};
    use elastic_moe::device::Timings;
    use elastic_moe::engine::CostModel;
    use elastic_moe::workload::{RateProfile, WorkloadGen, WorkloadSpec};

    let m = model::dsv2_lite();
    let sim = ServingSim::new(
        CostModel::new(m.clone(), Timings::cloudmatrix()),
        SloConfig::new(8.0, 1.5),
    );
    let mut method =
        elastic_moe::experiments::common::make_method("elastic", &m, 6)
            .unwrap();
    // Long contexts at moderate load so ~10 sequences are mid-decode at
    // the command — their (roughly consecutive) ids cover every DP-rank
    // residue, including the departing replica's.
    let mut gen = WorkloadGen::new(WorkloadSpec {
        prompt_len: 4000,
        decode_min: 150,
        decode_max: 250,
        profile: RateProfile::Fixed(1.2),
        seed: 31,
    });
    let arrivals = gen.arrivals_until(140.0);
    let expected: HashMap<u64, usize> = arrivals
        .iter()
        .map(|r| (r.id, r.max_new_tokens))
        .collect();

    let par = |n: usize| {
        ParallelConfig::standard(n / 2, 2, (0..n).collect()).unwrap()
    };
    let out = sim
        .run(
            method.as_mut(),
            &par(6),
            arrivals,
            Trigger::Manual(vec![(40.0, par(4))]),
            140.0,
        )
        .unwrap();

    // The event actually planned a handoff with suspended copy legs.
    assert_eq!(out.scaling_events.len(), 1);
    let ev = &out.scaling_events[0];
    let handoff = ev.kv_handoff.as_ref().expect("migrate policy plans");
    assert!(
        !handoff.suspend_ids().is_empty(),
        "scale-down must suspend the departing replica's sequences"
    );
    assert!(ev.intake_pause.is_some(), "pause window still declared");
    assert!(out.handoff.remapped > 0 && out.handoff.copied > 0);

    // Exactly-once: every arrival finishes once, none twice (a request
    // that was both drained-restarted and migrated would finish twice or
    // overproduce).
    let mut seen = HashSet::new();
    for r in out.recorder.all() {
        assert!(seen.insert(r.id), "request {} finished twice", r.id);
        assert_eq!(
            r.tokens,
            expected[&r.id],
            "request {} token count not conserved",
            r.id
        );
    }
    assert_eq!(seen.len(), expected.len(), "every request finishes");

    // Conservation across the event: adopted progress + restarted losses
    // account for every in-flight sequence exactly once.
    let inflight = out.handoff.remapped
        + out.handoff.copied
        + out.handoff.recomputed;
    assert!(inflight <= expected.len());
    assert!(out.handoff.adopted_tokens > 0);
}

/// Regression for the chaos abort path (companion to
/// `intake_pause_and_suspend_window_compose`): a P2P fault on the first
/// live-KV copy leg aborts a scale-down mid-handoff. The rollback must
/// resume every suspended sequence on its origin replica, conserve KV
/// blocks (plan audit), leave the configuration untouched, keep every
/// request finishing exactly once — and leave the HMM consistent enough
/// that a later scale-down on the same state succeeds.
#[test]
fn aborted_mid_copy_scale_down_resumes_suspended_and_conserves_blocks() {
    use std::collections::HashMap;

    use elastic_moe::chaos::{
        check_all, FaultInjector, FaultKind, FaultPlan, TraceEvent,
    };
    use elastic_moe::config::SloConfig;
    use elastic_moe::coordinator::{ServingSim, Trigger};
    use elastic_moe::device::Timings;
    use elastic_moe::engine::CostModel;
    use elastic_moe::workload::{RateProfile, WorkloadGen, WorkloadSpec};

    let m = model::dsv2_lite();
    let mut sim = ServingSim::new(
        CostModel::new(m.clone(), Timings::cloudmatrix()),
        SloConfig::new(8.0, 1.5),
    );
    // Event 0 (the t=40 scale-down) faults on its first KV copy leg;
    // event 1 (the t=80 retry) is clean.
    let inj = Rc::new(RefCell::new(FaultInjector::new(FaultPlan::single(
        0,
        FaultKind::KvCopyFail { after_legs: 1 },
    ))));
    sim.injector = Some(inj.clone());
    let mut method = elastic_moe::experiments::common::elastic_with_opts(
        &m,
        6,
        Default::default(),
        Default::default(),
    );
    method.hmm.set_fault_injector(inj);

    // Same long-context traffic as the compose test: ~10 sequences are
    // mid-decode at the command, covering the departing replica's ids.
    let mut gen = WorkloadGen::new(WorkloadSpec {
        prompt_len: 4000,
        decode_min: 150,
        decode_max: 250,
        profile: RateProfile::Fixed(1.2),
        seed: 31,
    });
    let arrivals = gen.arrivals_until(140.0);
    let expected: HashMap<u64, usize> = arrivals
        .iter()
        .map(|r| (r.id, r.max_new_tokens))
        .collect();

    let par = |n: usize| {
        ParallelConfig::standard(n / 2, 2, (0..n).collect()).unwrap()
    };
    let out = sim
        .run(
            &mut method,
            &par(6),
            arrivals,
            Trigger::Manual(vec![(40.0, par(4)), (80.0, par(4))]),
            140.0,
        )
        .unwrap();

    // First event aborted and rolled back; second succeeded.
    assert_eq!(out.scaling_events.len(), 2);
    let ev = &out.scaling_events[0];
    let abort = ev.aborted.as_ref().expect("KV-leg fault must abort");
    assert!(abort.rolled_back);
    assert!(matches!(abort.fault, FaultKind::KvCopyFail { .. }));
    assert_eq!(ev.new_parallel.n_devices(), 6, "origin config restored");
    assert!(out.scaling_events[1].aborted.is_none());
    assert_eq!(out.scaling_events[1].new_parallel.n_devices(), 4);
    assert_eq!(
        out.device_timeline.iter().map(|&(_, d)| d).collect::<Vec<_>>(),
        vec![6, 4],
        "the abort never changes capacity; the retry does"
    );

    // The aborted event's plan still conserves every live block.
    let audit = ev.plan_audit.expect("snapshot was planned against");
    assert!(audit.blocks_conserved(), "{audit:?}");
    assert!(audit.kv_copied_blocks > 0, "copy legs were planned");

    // Every sequence the abort suspended was resumed on its origin
    // replica (event 0), none adopted or restarted there.
    let suspended: Vec<u64> = out
        .trace
        .events
        .iter()
        .filter_map(|e| match e {
            TraceEvent::Suspended { event: 0, id, .. } => Some(*id),
            _ => None,
        })
        .collect();
    assert!(
        !suspended.is_empty(),
        "the mid-copy fault must catch suspended sequences"
    );
    let resumed: Vec<u64> = out
        .trace
        .events
        .iter()
        .filter_map(|e| match e {
            TraceEvent::Resumed { event: 0, id, .. } => Some(*id),
            _ => None,
        })
        .collect();
    let (mut a, mut b) = (suspended.clone(), resumed);
    a.sort_unstable();
    b.sort_unstable();
    assert_eq!(a, b, "every suspended sequence resumes, exactly those");

    // Exactly-once finish with full token conservation, plus the whole
    // invariant catalog over the trace.
    assert_eq!(out.recorder.count(), expected.len());
    for r in out.recorder.all() {
        assert_eq!(r.tokens, expected[&r.id], "request {}", r.id);
    }
    let violations = check_all(&out.trace);
    assert!(violations.is_empty(), "{violations:?}");
}

/// The reconciler is "killed" mid-scale: a KV copy-leg fault aborts the
/// fleet's first scale-down after its step was already enacted. Because
/// the planner re-derives steps from observed state each round — never
/// from a replay log — the same resize is simply planned again on a
/// later tick, the retry completes, and the fleet converges to the
/// originally declared spec with every request finishing exactly once
/// and zero duplicated migrations.
#[test]
fn aborted_reconcile_step_is_rederived_from_observed_state() {
    use std::collections::HashMap;

    use elastic_moe::chaos::{
        check_all, FaultInjector, FaultKind, FaultPlan, TraceEvent,
    };
    use elastic_moe::config::SloConfig;
    use elastic_moe::coordinator::{
        FleetLimits, FleetPolicy, FleetSim, PolicyMode, Router,
    };
    use elastic_moe::device::Timings;
    use elastic_moe::engine::CostModel;
    use elastic_moe::scaling::ScalingMethod;
    use elastic_moe::workload::{RateProfile, WorkloadGen, WorkloadSpec};

    let m = model::dsv2_lite();
    let mut sim = FleetSim::new(
        CostModel::new(m.clone(), Timings::cloudmatrix()),
        SloConfig::scale_up_demo(),
        Router::JoinShortestQueue,
    );
    // One replica, vertical only, rebalances disabled: scale event 0 is
    // the burst's 2->4 step (pure remap, the armed fault cannot fire),
    // event 1 the post-burst 4->2 step whose departing device group
    // forces live-KV copies — its first copy leg faults and the event
    // aborts after rollback. The event-2 retry is clean.
    let inj = Rc::new(RefCell::new(FaultInjector::new(FaultPlan::single(
        1,
        FaultKind::KvCopyFail { after_legs: 1 },
    ))));
    sim.injector = Some(inj.clone());

    let limits = FleetLimits {
        pool_devices: 4,
        replica_base: 2,
        replica_max: 4,
        step: 2,
        min_replicas: 1,
    };
    let mut policy = FleetPolicy::new(
        PolicyMode::VerticalOnly,
        limits,
        SloConfig::scale_up_demo(),
    );
    policy.estimator.up_patience = 1;
    policy.estimator.cooldown = 10.0;
    policy.replica_cooldown = 10.0;
    policy.rebalance_threshold = f64::INFINITY;

    let inj2 = inj.clone();
    let mut factory =
        move |_i: usize| -> anyhow::Result<Box<dyn ScalingMethod>> {
            let mut e =
                elastic_moe::experiments::common::elastic_with_opts(
                    &model::dsv2_lite(),
                    4,
                    Default::default(),
                    Default::default(),
                );
            e.hmm.set_fault_injector(inj2.clone());
            Ok(Box::new(e))
        };

    let horizon = 140.0;
    let mut gen = WorkloadGen::new(WorkloadSpec {
        prompt_len: 2000,
        decode_min: 150,
        decode_max: 250,
        profile: RateProfile::Burst {
            base: 0.8,
            factor: 6.0,
            start: 10.0,
            len: 30.0,
        },
        seed: 17,
    });
    let arrivals = gen.arrivals_until(horizon);
    let expected: HashMap<u64, usize> = arrivals
        .iter()
        .map(|r| (r.id, r.max_new_tokens))
        .collect();

    let out = sim
        .run(&mut policy, &mut factory, 1, arrivals, horizon)
        .unwrap();

    // Exactly one event aborted, on the armed KV-copy fault, and a
    // later scale-down completed: the interrupted step was re-derived
    // and retried, not replayed.
    let aborted: Vec<_> = out
        .scaling_events
        .iter()
        .filter_map(|e| e.aborted.as_ref())
        .collect();
    assert_eq!(aborted.len(), 1, "exactly one abort");
    assert!(aborted[0].rolled_back);
    assert!(matches!(aborted[0].fault, FaultKind::KvCopyFail { .. }));
    assert!(
        out.scaling_events
            .iter()
            .any(|e| e.aborted.is_none() && e.new_parallel.n_devices() == 2),
        "the re-derived scale-down must complete"
    );

    // The same step was planned and enacted (applied, not no-op'd) at
    // least twice: once before the abort, once as the re-derivation.
    let down_steps = out
        .trace
        .events
        .iter()
        .filter(|e| {
            matches!(
                e,
                TraceEvent::ReconcileStep {
                    replica: 0,
                    step,
                    applied: true,
                    ..
                } if step == "resize->2"
            )
        })
        .count();
    assert!(
        down_steps >= 2,
        "abort must force a re-derived retry ({down_steps} enactments)"
    );

    // Converged back onto the declared spec: the fleet ends at the
    // post-burst footprint with zero drift in the final round.
    assert_eq!(
        out.device_timeline.last().map(|&(_, d)| d),
        Some(2),
        "fleet must end at the declared 2-device footprint"
    );
    let last_drift = out
        .trace
        .events
        .iter()
        .rev()
        .find_map(|e| match e {
            TraceEvent::SpecDeclared { drift, .. } => Some(*drift),
            _ => None,
        })
        .expect("reconcile rounds were declared");
    assert_eq!(last_drift, 0, "final round must be converged");

    // No duplicated migrations anywhere: every request finished exactly
    // once with its full token budget, and the whole invariant catalog
    // (KV conservation across the abort included) holds.
    assert_eq!(out.recorder.count(), expected.len());
    for r in out.recorder.all() {
        assert_eq!(r.tokens, expected[&r.id], "request {}", r.id);
    }
    let violations = check_all(&out.trace);
    assert!(violations.is_empty(), "{violations:?}");
}
