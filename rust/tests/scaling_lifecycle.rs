//! Full scaling lifecycles per method: boot -> up -> down -> up again,
//! asserting the paper's qualitative contract for each method (downtime,
//! peak memory, device usage, repeatability).

use std::cell::RefCell;
use std::rc::Rc;

use elastic_moe::config::model::dsv2_lite;
use elastic_moe::config::ParallelConfig;
use elastic_moe::device::Cluster;
use elastic_moe::experiments::common::{make_method, par, KV_BYTES};
use elastic_moe::scaling::{ColdRestart, ScalingMethod};

fn m() -> elastic_moe::config::ModelConfig {
    dsv2_lite()
}

#[test]
fn elastic_up_down_up_is_stable() {
    let model = m();
    let mut meth = make_method("elastic", &model, 8).unwrap();
    meth.boot(&par(&model, 4).unwrap()).unwrap();
    let up1 = meth.scale(&par(&model, 6).unwrap()).unwrap();
    let down = meth.scale(&par(&model, 4).unwrap()).unwrap();
    let up2 = meth.scale(&par(&model, 8).unwrap()).unwrap();
    for (label, out) in
        [("up1", &up1), ("down", &down), ("up2", &up2)]
    {
        assert_eq!(out.metrics.downtime, 0.0, "{label}");
        assert!(out.ready_after < 15.0, "{label}: {}", out.ready_after);
        assert!(out.preserves_inflight, "{label}");
    }
    // Second scale-up to a standby-cached config is not slower than the
    // first by more than noise.
    assert!(up2.ready_after < up1.ready_after * 2.0);
    assert_eq!(meth.current().unwrap().n_devices(), 8);
}

#[test]
fn elastic_memory_returns_to_steady_state() {
    let model = m();
    let cluster = Rc::new(RefCell::new(Cluster::cloudmatrix(6)));
    let hmm = elastic_moe::hmm::control::HmmControl::new(
        cluster.clone(),
        model.clone(),
        Default::default(),
    );
    let imm = elastic_moe::imm::manager::InstanceManager::new(
        Default::default(),
        elastic_moe::device::Timings::cloudmatrix(),
    );
    let mut meth =
        elastic_moe::scaling::ElasticMoE::new(hmm, imm, KV_BYTES);
    meth.boot(&par(&model, 4).unwrap()).unwrap();
    let steady4 = cluster.borrow().used_over(&[0, 1, 2, 3]);
    meth.scale(&par(&model, 6).unwrap()).unwrap();
    let after_up = cluster.borrow().used_over(&[0, 1, 2, 3, 4, 5]);
    // After switchover (deferred frees applied inside scale), usage on the
    // original 4 devices must have DROPPED (experts moved away), and the
    // 6-device total must be bounded by ~steady + 2 new device loads.
    let on_old = cluster.borrow().used_over(&[0, 1, 2, 3]);
    assert!(on_old < steady4, "evicted experts not freed: {on_old} vs {steady4}");
    assert!(after_up > steady4, "new devices hold weights");
    meth.scale(&par(&model, 4).unwrap()).unwrap();
    let back4 = cluster.borrow().used_over(&[0, 1, 2, 3]);
    // All experts back on 4 devices: usage within rounding of steady4.
    let ratio = back4 as f64 / steady4 as f64;
    assert!(
        (0.95..1.05).contains(&ratio),
        "steady {steady4} vs back {back4}"
    );
    // Devices 4,5 may retain attention shards until instance teardown but
    // hold no expert pages.
    let c = cluster.borrow();
    assert_eq!(
        c.devices[4]
            .hbm
            .used_by_kind(elastic_moe::device::RegionKind::ExpertWeights),
        0
    );
}

#[test]
fn cold_restart_repeats_full_boot_every_time() {
    let model = m();
    let cluster = Rc::new(RefCell::new(Cluster::cloudmatrix(8)));
    let mut meth = ColdRestart::new(cluster, model.clone(), KV_BYTES);
    meth.boot(&par(&model, 4).unwrap()).unwrap();
    let a = meth.scale(&par(&model, 6).unwrap()).unwrap();
    let b = meth.scale(&par(&model, 8).unwrap()).unwrap();
    // Both transitions pay the full cold boot with downtime.
    for out in [&a, &b] {
        assert!(out.downtime.is_some());
        assert!(out.ready_after > 30.0);
        assert!(!out.preserves_inflight);
    }
    // Bigger target, longer load.
    assert!(b.ready_after > a.ready_after * 0.9);
}

#[test]
fn methods_disagree_only_in_choreography_not_capacity() {
    // After scaling completes, elastic and cold restart land on the same
    // configuration (same devices, same parallel layout).
    let model = m();
    let mut e = make_method("elastic", &model, 6).unwrap();
    let mut c = make_method("cold", &model, 6).unwrap();
    e.boot(&par(&model, 4).unwrap()).unwrap();
    c.boot(&par(&model, 4).unwrap()).unwrap();
    let eo = e.scale(&par(&model, 6).unwrap()).unwrap();
    let co = c.scale(&par(&model, 6).unwrap()).unwrap();
    assert_eq!(eo.new_parallel.label(), co.new_parallel.label());
    assert_eq!(eo.new_parallel.devices, co.new_parallel.devices);
    // ...but the transition costs differ by ~an order of magnitude.
    assert!(eo.ready_after * 5.0 < co.ready_after);
}

#[test]
fn elastic_rejects_invalid_targets() {
    let model = m();
    let mut meth = make_method("elastic", &model, 8).unwrap();
    meth.boot(&par(&model, 4).unwrap()).unwrap();
    // TP change rejected.
    let bad_tp = ParallelConfig::standard(1, 4, (0..4).collect()).unwrap();
    assert!(meth.scale(&bad_tp).is_err());
    // EP beyond expert count rejected (128 devices > 64 experts).
    // (construct directly: the config itself is fine, the model check
    // fails in plan_scale)
    let too_many = ParallelConfig::standard(64, 2, (0..128).collect()).unwrap();
    assert!(meth.scale(&too_many).is_err());
}

#[test]
fn repeated_scaling_does_not_leak_memory() {
    let model = m();
    let cluster = Rc::new(RefCell::new(Cluster::cloudmatrix(8)));
    let hmm = elastic_moe::hmm::control::HmmControl::new(
        cluster.clone(),
        model.clone(),
        Default::default(),
    );
    let imm = elastic_moe::imm::manager::InstanceManager::new(
        Default::default(),
        elastic_moe::device::Timings::cloudmatrix(),
    );
    let mut meth =
        elastic_moe::scaling::ElasticMoE::new(hmm, imm, KV_BYTES);
    meth.boot(&par(&model, 4).unwrap()).unwrap();
    meth.scale(&par(&model, 6).unwrap()).unwrap();
    meth.scale(&par(&model, 4).unwrap()).unwrap();
    let usage1 = cluster.borrow().used_over(&[0, 1, 2, 3, 4, 5, 6, 7]);
    for _ in 0..3 {
        meth.scale(&par(&model, 6).unwrap()).unwrap();
        meth.scale(&par(&model, 4).unwrap()).unwrap();
    }
    let usage2 = cluster.borrow().used_over(&[0, 1, 2, 3, 4, 5, 6, 7]);
    assert_eq!(usage1, usage2, "memory leak across scaling cycles");
}
