//! Property-based tests (proplite harness) over the coordinator and HMM
//! invariants — the L3 analogue of the hypothesis sweeps on L1/L2.

use std::cell::RefCell;
use std::rc::Rc;

use elastic_moe::config::model::dsv2_lite;
use elastic_moe::config::{ParallelConfig, SloConfig};
use elastic_moe::coordinator::{ServingSim, Trigger};
use elastic_moe::device::{Cluster, Timings};
use elastic_moe::engine::{CostModel, PagedKv};
use elastic_moe::hmm::control::{HmmControl, HmmOptions};
use elastic_moe::obs::LogHistogram;
use elastic_moe::util::json::{self, Json};
use elastic_moe::util::proplite::check;
use elastic_moe::util::rng::Rng;
use elastic_moe::util::stats;
use elastic_moe::workload::{RateProfile, WorkloadGen, WorkloadSpec};

fn par(n: usize) -> ParallelConfig {
    ParallelConfig::standard(n / 2, 2, (0..n).collect()).unwrap()
}

/// After any sequence of random scale events, every expert of every layer
/// is bound exactly once across the cluster's vpage tables, on a device of
/// the current configuration.
#[test]
fn prop_expert_placement_is_a_partition_under_random_scaling() {
    check("expert partition", 25, |rng: &mut Rng| {
        let m = dsv2_lite();
        let cluster = Rc::new(RefCell::new(Cluster::cloudmatrix(12)));
        let mut hmm =
            HmmControl::new(cluster, m.clone(), HmmOptions::default());
        let mut cur = 2 + 2 * rng.below(3) as usize; // 2, 4 or 6
        hmm.load_initial(&par(cur), 4 << 30).unwrap();
        for _ in 0..rng.range(1, 5) {
            let next = 2 + 2 * rng.below(6) as usize; // 2..12
            if next == cur {
                continue;
            }
            let to = par(next);
            let plan = hmm.plan_scale(&to).unwrap();
            hmm.execute_plan(&plan, &to).unwrap();
            hmm.apply_deferred_frees().unwrap();
            cur = next;

            // Partition check over the vpage tables.
            for layer in [0usize, (m.n_layers - 1) as usize] {
                let mut seen = vec![0u32; m.n_experts as usize];
                for d in 0..12 {
                    if let Some(w) = hmm.worker(d) {
                        for e in w.vpages.experts(layer) {
                            seen[e] += 1;
                            assert!(
                                d < cur,
                                "expert {e} bound on dev {d} outside config of {cur}"
                            );
                        }
                    }
                }
                assert!(
                    seen.iter().all(|&c| c == 1),
                    "not a partition at layer {layer}: {seen:?}"
                );
            }
            // Balance check: max-min <= 1 experts per rank.
            let counts: Vec<usize> = (0..cur)
                .map(|d| hmm.worker(d).map(|w| w.vpages.experts(0).len()).unwrap_or(0))
                .collect();
            let (mn, mx) = (
                *counts.iter().min().unwrap(),
                *counts.iter().max().unwrap(),
            );
            assert!(mx - mn <= 1, "imbalanced placement {counts:?}");
        }
    });
}

/// Scaling plans move the minimal number of experts: exactly the overflow
/// implied by the balanced target counts.
#[test]
fn prop_plan_migrations_are_minimal() {
    check("minimal migrations", 25, |rng: &mut Rng| {
        let m = dsv2_lite();
        let cluster = Rc::new(RefCell::new(Cluster::cloudmatrix(12)));
        let mut hmm =
            HmmControl::new(cluster, m.clone(), HmmOptions::default());
        let from_n = 2 + 2 * rng.below(5) as usize;
        hmm.load_initial(&par(from_n), 4 << 30).unwrap();
        let to_n = 2 + 2 * rng.below(6) as usize;
        if to_n == from_n {
            return;
        }
        let plan = hmm.plan_scale(&par(to_n)).unwrap();
        // Lower bound per layer: sum over devices of max(0, cur - target).
        let e = m.n_experts as usize;
        let base = e / to_n;
        let extra = e % to_n;
        let mut lower_bound = 0usize;
        for layer in 0..m.n_layers as usize {
            let mut cur_counts = vec![0usize; 12];
            for d in 0..12 {
                if let Some(w) = hmm.worker(d) {
                    cur_counts[d] = w.vpages.experts(layer).len();
                }
            }
            for d in 0..12 {
                let target = if d < to_n {
                    base + usize::from(d < extra)
                } else {
                    0
                };
                lower_bound += cur_counts[d].saturating_sub(target);
            }
        }
        assert_eq!(
            plan.migrated_expert_count(),
            lower_bound,
            "{from_n}->{to_n}"
        );
    });
}

/// No request is ever lost or duplicated across random elastic scaling
/// events: everything submitted eventually finishes exactly once.
#[test]
fn prop_no_request_lost_across_scaling() {
    check("request conservation", 8, |rng: &mut Rng| {
        let m = dsv2_lite();
        let sim = ServingSim::new(
            CostModel::new(m.clone(), Timings::cloudmatrix()),
            SloConfig::new(1e9, 1e9),
        );
        let mut method = elastic_moe::experiments::common::make_method(
            ["elastic", "cold", "extravagant"][rng.below(2) as usize],
            &m,
            8,
        )
        .unwrap();
        let mut gen = WorkloadGen::new(WorkloadSpec {
            prompt_len: 500,
            decode_min: 20,
            decode_max: 60,
            profile: RateProfile::Fixed(rng.uniform(1.0, 6.0)),
            seed: rng.next_u64(),
        });
        let horizon = 90.0;
        let arrivals = gen.arrivals_until(horizon);
        let n = arrivals.len();
        let triggers: Vec<(f64, ParallelConfig)> = (0..rng.range(1, 3))
            .map(|i| (20.0 + 25.0 * i as f64, par(if i % 2 == 0 { 6 } else { 4 })))
            .collect();
        let out = sim
            .run(
                method.as_mut(),
                &par(4),
                arrivals,
                Trigger::Manual(triggers),
                horizon,
            )
            .unwrap();
        assert_eq!(
            out.recorder.count(),
            n,
            "requests lost or duplicated"
        );
        // Each id recorded exactly once (completion, not drop-and-retry).
        let mut finishes = std::collections::HashMap::new();
        for r in out.recorder.all() {
            *finishes.entry((r.arrival * 1e6) as u64).or_insert(0) += 1;
        }
        let _ = finishes;
    });
}

/// VpageTable under random op sequences: bind/unbind round-trips, double
/// binds are rejected without corrupting state, and `remap_count` grows
/// monotonically — bumping exactly once per successful bind/unbind (twice
/// per rebind) and never on a failed op.
#[test]
fn prop_vpage_table_matches_model_under_random_ops() {
    use elastic_moe::hmm::VpageTable;
    use std::collections::BTreeMap;

    check("vpage model equivalence", 100, |rng: &mut Rng| {
        let mut table = VpageTable::new();
        // Mirror model: (layer, expert) -> region.
        let mut model: BTreeMap<(usize, usize), u64> = BTreeMap::new();
        let mut next_region = 100u64;
        let layers = 1 + rng.below(4) as usize;
        let experts = 1 + rng.below(8) as usize;
        for _ in 0..120 {
            let layer = rng.below(layers as u64) as usize;
            let expert = rng.below(experts as u64) as usize;
            let key = (layer, expert);
            let before = table.remap_count;
            match rng.below(3) {
                0 => {
                    let region = next_region;
                    next_region += 1;
                    let r = table.bind(layer, expert, region);
                    if model.contains_key(&key) {
                        assert!(r.is_err(), "double bind must be rejected");
                        assert_eq!(
                            table.remap_count, before,
                            "failed bind must not count as a remap"
                        );
                    } else {
                        r.unwrap();
                        model.insert(key, region);
                        assert_eq!(table.remap_count, before + 1);
                    }
                }
                1 => {
                    let r = table.unbind(layer, expert);
                    match model.remove(&key) {
                        Some(region) => {
                            assert_eq!(r.unwrap(), region, "round-trip");
                            assert_eq!(table.remap_count, before + 1);
                        }
                        None => {
                            assert!(r.is_err(), "unbound unbind must fail");
                            assert_eq!(table.remap_count, before);
                        }
                    }
                }
                _ => {
                    let region = next_region;
                    next_region += 1;
                    let r = table.rebind(layer, expert, region);
                    match model.get_mut(&key) {
                        Some(old) => {
                            assert_eq!(r.unwrap(), *old, "rebind returns old");
                            *old = region;
                            assert_eq!(table.remap_count, before + 2);
                        }
                        None => {
                            assert!(r.is_err());
                            assert_eq!(table.remap_count, before);
                        }
                    }
                }
            }
            assert!(
                table.remap_count >= before,
                "remap_count must be monotone"
            );
            // Full-state equivalence with the mirror.
            assert_eq!(table.bound_count(), model.len());
            for l in 0..layers {
                for e in 0..experts {
                    assert_eq!(
                        table.lookup(l, e),
                        model.get(&(l, e)).copied(),
                        "lookup mismatch at ({l}, {e})"
                    );
                }
            }
            let bindings = table.all_bindings();
            assert_eq!(bindings.len(), model.len());
            for (l, e, r) in bindings {
                assert_eq!(model.get(&(l, e)), Some(&r));
            }
        }
    });
}

/// Paged KV never double-books a block and always conserves the pool.
#[test]
fn prop_paged_kv_conserves_blocks() {
    check("kv conservation", 100, |rng: &mut Rng| {
        let blocks = rng.range(8, 128) as usize;
        let bt = rng.range(1, 32) as usize;
        let mut kv = PagedKv::new(blocks, bt);
        let mut live: Vec<(u64, usize)> = Vec::new(); // (id, tokens)
        let mut next_id = 1u64;
        let mut expected_used = 0usize;
        for _ in 0..200 {
            match rng.below(3) {
                0 => {
                    let tokens = rng.range(1, 64) as usize;
                    let need = tokens.div_ceil(bt);
                    let id = next_id;
                    if kv.can_admit(tokens) {
                        kv.admit(id, tokens).unwrap();
                        next_id += 1;
                        live.push((id, tokens));
                        expected_used += need;
                    } else {
                        assert!(kv.admit(id, tokens).is_err());
                    }
                }
                1 => {
                    if let Some(i) =
                        (!live.is_empty()).then(|| rng.below(live.len() as u64) as usize)
                    {
                        let (id, tokens) = &mut live[i];
                        let before = tokens.div_ceil(bt);
                        if kv.append_token(*id).is_ok() {
                            *tokens += 1;
                            let after = tokens.div_ceil(bt);
                            expected_used += after - before;
                        }
                    }
                }
                _ => {
                    if !live.is_empty() {
                        let i = rng.below(live.len() as u64) as usize;
                        let (id, tokens) = live.swap_remove(i);
                        expected_used -= tokens.div_ceil(bt);
                        kv.release(id);
                    }
                }
            }
            assert_eq!(kv.used_blocks(), expected_used);
            assert_eq!(
                kv.used_blocks() + kv.free_blocks(),
                kv.total_blocks()
            );
        }
    });
}

/// JSON writer/parser round-trip over random documents.
#[test]
fn prop_json_roundtrip() {
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bool(0.5)),
            2 => Json::Num((rng.next_u64() % 1_000_000) as f64 / 8.0),
            3 => {
                let len = rng.below(12) as usize;
                Json::Str(
                    (0..len)
                        .map(|_| {
                            char::from_u32(
                                32 + rng.below(500) as u32,
                            )
                            .unwrap_or('x')
                        })
                        .collect(),
                )
            }
            4 => Json::Arr(
                (0..rng.below(5))
                    .map(|_| random_json(rng, depth - 1))
                    .collect(),
            ),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| {
                        (format!("k{i}"), random_json(rng, depth - 1))
                    })
                    .collect(),
            ),
        }
    }
    check("json roundtrip", 300, |rng: &mut Rng| {
        let doc = random_json(rng, 3);
        let text = doc.to_string();
        let parsed = json::parse(&text).expect("reparse");
        assert_eq!(parsed, doc, "{text}");
    });
}

/// The simulated clock composed with the engine never goes backwards and
/// finished requests have consistent timestamps.
#[test]
fn prop_request_timestamps_are_ordered() {
    check("timestamp ordering", 10, |rng: &mut Rng| {
        let m = dsv2_lite();
        let sim = ServingSim::new(
            CostModel::new(m.clone(), Timings::cloudmatrix()),
            SloConfig::strict(),
        );
        let mut method = elastic_moe::experiments::common::make_method(
            "elastic", &m, 6,
        )
        .unwrap();
        let mut gen = WorkloadGen::new(WorkloadSpec {
            prompt_len: 300,
            decode_min: 5,
            decode_max: 40,
            profile: RateProfile::Fixed(rng.uniform(0.5, 4.0)),
            seed: rng.next_u64(),
        });
        let arrivals = gen.arrivals_until(40.0);
        let out = sim
            .run(
                method.as_mut(),
                &par(4),
                arrivals,
                Trigger::Manual(vec![]),
                40.0,
            )
            .unwrap();
        for r in out.recorder.all() {
            assert!(r.ttft >= 0.0, "negative ttft");
            assert!(r.finished >= r.arrival, "finished before arrival");
            assert!(r.tpot >= 0.0);
        }
    });
}

/// PagedKv block accounting: under any random sequence of
/// admit/append/release operations, no block is ever leaked or double
/// freed (`free + used == total` at every step), per-sequence tables
/// always hold exactly `ceil(len / block_tokens)` blocks, and
/// `can_admit` agrees with `admit`'s success.
#[test]
fn prop_paged_kv_alloc_free_never_leaks() {
    check("paged kv accounting", 80, |rng: &mut Rng| {
        let block_tokens = 1 + rng.below(32) as usize;
        let n_blocks = 1 + rng.below(80) as usize;
        let mut kv = PagedKv::new(n_blocks, block_tokens);
        let mut live: Vec<u64> = Vec::new();
        let mut next_id = 0u64;

        let invariants = |kv: &PagedKv, live: &[u64]| {
            assert_eq!(
                kv.free_blocks() + kv.used_blocks(),
                kv.total_blocks(),
                "pool leaked or double-freed"
            );
            assert_eq!(kv.active_requests(), live.len());
            let mut sum = 0;
            for &id in live {
                let len = kv.seq_len(id).expect("live seq has a length");
                let blocks = kv.seq_blocks(id).unwrap();
                assert_eq!(
                    blocks,
                    len.max(1).div_ceil(block_tokens),
                    "table size drifted from length"
                );
                sum += blocks;
            }
            assert_eq!(sum, kv.used_blocks(), "tables != used blocks");
        };

        for _ in 0..rng.range(1, 150) {
            match rng.below(4) {
                0 | 1 => {
                    // Admit: can_admit must agree with the outcome.
                    let tokens = 1 + rng.below(3 * block_tokens as u64 + 40)
                        as usize;
                    let predicted = kv.can_admit(tokens);
                    let id = next_id;
                    next_id += 1;
                    let outcome = kv.admit(id, tokens);
                    assert_eq!(
                        predicted,
                        outcome.is_ok(),
                        "can_admit({tokens}) said {predicted}"
                    );
                    if outcome.is_ok() {
                        live.push(id);
                    }
                }
                2 => {
                    // Grow a random live sequence (failure must not
                    // corrupt state; retrying later may succeed).
                    if !live.is_empty() {
                        let id =
                            live[rng.below(live.len() as u64) as usize];
                        let before = kv.seq_len(id).unwrap();
                        if kv.append_token(id).is_err() {
                            assert_eq!(kv.seq_len(id), Some(before));
                            assert_eq!(kv.free_blocks(), 0);
                        } else {
                            assert_eq!(kv.seq_len(id), Some(before + 1));
                        }
                    }
                }
                _ => {
                    // Release a random live sequence; double release is
                    // a no-op.
                    if !live.is_empty() {
                        let i = rng.below(live.len() as u64) as usize;
                        let id = live.swap_remove(i);
                        kv.release(id);
                        kv.release(id);
                    }
                }
            }
            invariants(&kv, &live);
        }

        // Releasing everything returns the pool to pristine.
        for id in live.drain(..) {
            kv.release(id);
        }
        assert_eq!(kv.free_blocks(), kv.total_blocks());
        assert_eq!(kv.used_blocks(), 0);
        assert_eq!(kv.active_requests(), 0);
    });
}

/// Placement-solver conformance under random load stats: every expert is
/// placed exactly once on a device of the target set (forced movers —
/// home departed or home over the capacity cap — included), the
/// per-device capacity holds, discretionary migration bytes never exceed
/// the budget, the discretionary/forced byte split decomposes the total
/// exactly, and the solver is deterministic.
#[test]
fn prop_placement_solver_places_all_within_budget() {
    use elastic_moe::placement::{solve_layer, LayerPlacementInput};

    check("placement solver", 120, |rng: &mut Rng| {
        let d = 2 + rng.below(5) as usize; // 2..=6 devices
        let devices: Vec<usize> = (0..d).map(|i| i * 3 + 1).collect();
        let n = d + rng.below(28) as usize; // experts >= devices
        // Current owners: mostly in the target set, some on departed
        // devices (their experts become forced movers).
        let current: Vec<usize> = (0..n)
            .map(|_| {
                if rng.bool(0.2) {
                    100 + rng.below(3) as usize
                } else {
                    devices[rng.below(d as u64) as usize]
                }
            })
            .collect();
        let load: Vec<f64> = (0..n)
            .map(|_| {
                if rng.bool(0.3) {
                    0.0
                } else {
                    rng.uniform(0.0, 20.0)
                }
            })
            .collect();
        let capacity = n.div_ceil(d) + rng.below(3) as usize;
        let budget_bytes = rng.below(4) * 1000;
        let bytes_per_expert = 1000u64;
        let inp = LayerPlacementInput {
            devices: &devices,
            current: &current,
            load: &load,
            bytes_per_expert,
            capacity,
            budget_bytes,
            uniform_prior: if rng.bool(0.5) { 0.25 } else { 0.0 },
        };
        let out = solve_layer(&inp);

        // Every expert placed exactly once, on a target device.
        assert_eq!(out.owner.len(), n);
        for (e, &o) in out.owner.iter().enumerate() {
            assert!(
                devices.contains(&o),
                "expert {e} placed on {o}, outside the target set"
            );
        }
        // Capacity respected everywhere (so forced movers fit too).
        for &dev in &devices {
            let c = out.owner.iter().filter(|&&o| o == dev).count();
            assert!(c <= capacity, "device {dev} over capacity: {c}");
        }
        // Budget: discretionary bytes within it; forced moves exempt but
        // the byte split must decompose the migrated total exactly.
        assert!(
            out.discretionary_bytes <= budget_bytes,
            "discretionary {} over budget {budget_bytes}",
            out.discretionary_bytes
        );
        assert_eq!(
            out.discretionary_bytes + out.forced_bytes,
            out.migrated as u64 * bytes_per_expert,
            "byte accounting must decompose into discretionary + forced"
        );
        // Deterministic on identical input.
        assert_eq!(out.owner, solve_layer(&inp).owner);
    });
}

/// A freshly sized pool admits what it promised: `from_bytes` either
/// errors (budget below one block) or yields a pool whose first
/// admission of up to `block_tokens` tokens succeeds.
#[test]
fn prop_paged_kv_from_bytes_is_usable_or_errors() {
    check("paged kv from_bytes", 120, |rng: &mut Rng| {
        let bytes_per_token = 1 + rng.below(4096);
        let block_tokens = 1 + rng.below(64) as usize;
        let budget = rng.below(1 << 24);
        match PagedKv::from_bytes(budget, bytes_per_token, block_tokens) {
            Ok(mut kv) => {
                assert!(kv.total_blocks() > 0);
                assert!(kv.can_admit(block_tokens));
                kv.admit(1, block_tokens).unwrap();
            }
            Err(_) => {
                // Refused exactly when the budget holds less than one
                // block's worth of tokens.
                assert!(
                    (budget / bytes_per_token) < block_tokens as u64,
                    "spurious error: budget {budget} holds a block"
                );
            }
        }
    });
}

/// The event queue is the simulators' determinism spine: random pushes —
/// with heavy timestamp ties and the full non-NaN float range including
/// infinities and signed zero — pop in strict `(at, seq)` order, i.e.
/// sorted by `total_cmp` on time with FIFO insertion order breaking
/// ties, and nothing is lost or duplicated.
#[test]
fn prop_event_queue_pops_in_time_then_insertion_order() {
    use elastic_moe::sim::EventQueue;

    check("event queue ordering", 200, |rng: &mut Rng| {
        let mut q = EventQueue::new();
        let n = rng.range(1, 200) as usize;
        for i in 0..n {
            // Coarse grid forces plenty of exact ties; occasionally throw
            // in the pathological floats the ordering must still total.
            let mut at = rng.below(16) as f64 * 0.25;
            if rng.bool(0.05) {
                at = f64::INFINITY;
            }
            if rng.bool(0.1) {
                at = -at;
            }
            q.push(at, i);
        }
        assert_eq!(q.len(), n);
        let mut prev: Option<(f64, usize)> = None;
        let mut popped = 0usize;
        while let Some(ev) = q.pop() {
            if let Some((pt, pi)) = prev {
                let ord = pt.total_cmp(&ev.at);
                assert!(
                    ord.is_le(),
                    "time went backwards: {pt} popped before {}",
                    ev.at
                );
                if ord.is_eq() {
                    assert!(
                        pi < ev.payload,
                        "tie at t={pt} must pop FIFO: {pi} then {}",
                        ev.payload
                    );
                }
            }
            prev = Some((ev.at, ev.payload));
            popped += 1;
        }
        assert_eq!(popped, n, "events lost or duplicated");
        assert!(q.is_empty());
    });
}

/// The telemetry log-histogram's percentile estimate is accurate to one
/// bucket: for any sample set and percentile, the estimate is at least
/// the exact nearest-rank percentile of the sorted samples (it reports
/// the upper edge of the rank sample's bucket) and exceeds it by at most
/// that bucket's width.
#[test]
fn prop_log_histogram_percentile_within_one_bucket() {
    check("histogram percentile accuracy", 150, |rng: &mut Rng| {
        let mut h = LogHistogram::latency();
        let n = rng.range(1, 200) as usize;
        let mut samples = Vec::with_capacity(n);
        for _ in 0..n {
            // Log-uniform over ~1e-5..100 s: spans underflow, many log
            // buckets, and (rarely) overflow of the latency shape.
            let x = 1e-4 * 2.0f64.powf(rng.uniform(-3.0, 20.0));
            h.record(x);
            samples.push(x);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut ps = vec![0.0, 50.0, 90.0, 99.0, 100.0];
        ps.push(rng.uniform(0.0, 100.0));
        for p in ps {
            let exact = stats::percentile_sorted(&samples, p);
            let est = h.percentile(p);
            let (lo, hi) = h.bucket_span(exact);
            let width = if hi.is_finite() { hi - lo } else { h.max() - lo };
            assert!(
                est >= exact - 1e-12,
                "p{p}: estimate {est} below exact {exact}"
            );
            assert!(
                est - exact <= width + 1e-12,
                "p{p}: estimate {est} more than one bucket ({width}) \
                 above exact {exact}"
            );
        }
    });
}

/// Model-based LRU conformance: against a naive Vec model, the standby
/// cache's capacity holds (absent pins), hits refresh recency, pinned
/// entries are never evicted, and eviction order matches the model.
#[test]
fn prop_lru_cache_matches_model_and_pins_protect() {
    use elastic_moe::imm::LruCache;

    check("lru model equivalence", 200, |rng: &mut Rng| {
        let cap = 1 + rng.below(6) as usize;
        let mut cache: LruCache<u64, u64> = LruCache::new(cap);
        // Model: (key, value, pinned), LRU order front -> back.
        let mut model: Vec<(u64, u64, bool)> = Vec::new();
        let pos = |m: &Vec<(u64, u64, bool)>, k: u64| {
            m.iter().position(|&(mk, _, _)| mk == k)
        };
        for step in 0..rng.range(5, 60) {
            let key = rng.below(10);
            match rng.below(5) {
                // insert (or replace): evict the LRU unpinned entry when
                // over capacity; replacing a key keeps its pin.
                0 | 1 => {
                    let val = step;
                    let evicted = cache.insert(key, val);
                    let pin = pos(&model, key)
                        .map(|p| model.remove(p).2)
                        .unwrap_or(false);
                    model.push((key, val, pin));
                    let expect = if model.len() > cap {
                        // Victim: LRU unpinned among pre-existing
                        // entries (never the newcomer itself).
                        let candidates = model.len() - 1;
                        model
                            .iter()
                            .take(candidates)
                            .position(|&(_, _, pinned)| !pinned)
                            .map(|p| model.remove(p))
                    } else {
                        None
                    };
                    assert_eq!(
                        evicted,
                        expect.map(|(k, v, _)| (k, v)),
                        "eviction mismatch at step {step}"
                    );
                }
                // take: a hit leaves the cache entirely.
                2 => {
                    let got = cache.take(&key);
                    let expect = pos(&model, key)
                        .map(|p| model.remove(p))
                        .map(|(_, v, _)| v);
                    assert_eq!(got, expect);
                }
                // touch: refresh recency.
                3 => {
                    let hit = cache.touch(&key);
                    let expect = pos(&model, key).map(|p| model.remove(p));
                    assert_eq!(hit, expect.is_some());
                    if let Some(e) = expect {
                        model.push(e);
                    }
                }
                // pin / unpin: the active instance must survive churn.
                _ => {
                    if rng.bool(0.5) {
                        let ok = cache.pin(&key);
                        assert_eq!(ok, pos(&model, key).is_some());
                        if let Some(p) = pos(&model, key) {
                            model[p].2 = true;
                        }
                    } else {
                        let ok = cache.unpin(&key);
                        assert_eq!(ok, pos(&model, key).is_some());
                        if let Some(p) = pos(&model, key) {
                            model[p].2 = false;
                        }
                    }
                }
            }
            // Invariants after every step.
            assert_eq!(cache.len(), model.len());
            let pinned = model.iter().filter(|&&(_, _, p)| p).count();
            assert!(
                cache.len() <= cap.max(pinned + 1),
                "cache exceeded its pin allowance: len {} cap {cap} \
                 pinned {pinned}",
                cache.len()
            );
            if pinned == 0 {
                assert!(
                    cache.len() <= cap,
                    "capacity exceeded with no pins: {} > {cap}",
                    cache.len()
                );
            }
            for &(k, _, p) in &model {
                assert!(cache.contains(&k), "model key {k} missing");
                assert_eq!(cache.is_pinned(&k), p);
            }
        }
    });
}

/// Reconciler planner algebra under random observed states and specs:
/// the planned step batch is **idempotent** (guard-applying it twice
/// lands on exactly the state of applying it once) and the reconcile
/// loop is **monotone** — re-planning after each application never grows
/// the spec drift, and drift reaches zero within the convergence bound.
///
/// The model applies steps with the same guards the fleet simulator
/// enacts (a step whose precondition no longer holds is a no-op), with
/// spec slot ids standing in for booted replica ids.
#[test]
fn prop_reconciler_plan_is_idempotent_and_monotone() {
    use elastic_moe::chaos::CONVERGENCE_ROUNDS;
    use elastic_moe::coordinator::{
        FleetSpec, PoolRole, ReconcileStep, Reconciler, ReplicaLoad,
        ReplicaSpec,
    };

    const NOW: f64 = 100.0;

    fn load(id: usize, rng: &mut Rng) -> ReplicaLoad {
        ReplicaLoad {
            id,
            devices: 2 * (1 + rng.below(3) as usize),
            occupancy: rng.uniform(0.0, 1.0),
            queue_depth: rng.below(10) as usize,
            busy: rng.bool(0.2),
            booting: false,
            draining: rng.bool(0.15),
            parked: rng.bool(0.2),
            imbalance: 1.0,
            last_heartbeat: if rng.bool(0.2) {
                NOW - 30.0 // stale past the deadline: eviction due
            } else {
                NOW - 1.0
            },
            role: PoolRole::Unified,
        }
    }

    fn random_state(rng: &mut Rng) -> (Vec<ReplicaLoad>, FleetSpec) {
        let n = 1 + rng.below(5) as usize;
        let loads: Vec<ReplicaLoad> =
            (0..n).map(|id| load(id, rng)).collect();
        let mut slots = Vec::new();
        for l in &loads {
            // A draining replica never reappears in a projected spec.
            if l.draining || rng.bool(0.2) {
                continue;
            }
            let parked = rng.bool(0.2);
            slots.push(ReplicaSpec {
                id: l.id,
                devices: if parked {
                    0
                } else {
                    2 * (1 + rng.below(3) as usize)
                },
                parked,
                role: PoolRole::Unified,
            });
        }
        if rng.bool(0.3) {
            // A brand-new slot the reconciler must boot.
            slots.push(ReplicaSpec {
                id: n + 5,
                devices: 2,
                parked: false,
                role: PoolRole::Unified,
            });
        }
        (loads, FleetSpec { replicas: slots, rebalance: None })
    }

    /// Guarded model application — mirrors the simulator's checked
    /// no-op enactment.
    fn apply(steps: &[ReconcileStep], loads: &mut Vec<ReplicaLoad>) {
        for s in steps {
            match *s {
                ReconcileStep::Resize { replica, to_devices } => {
                    if let Some(l) = loads.iter_mut().find(|l| {
                        l.id == replica
                            && !l.parked
                            && !l.draining
                            && !l.busy
                            && l.devices != to_devices
                    }) {
                        l.devices = to_devices;
                    }
                }
                ReconcileStep::Park { replica } => {
                    if let Some(l) = loads.iter_mut().find(|l| {
                        l.id == replica && !l.parked && !l.busy
                    }) {
                        l.parked = true;
                    }
                }
                ReconcileStep::Unpark { replica } => {
                    if let Some(l) = loads
                        .iter_mut()
                        .find(|l| l.id == replica && l.parked)
                    {
                        l.parked = false;
                        // Boot completion counts as a heartbeat in the
                        // simulator; without it a stale parked replica
                        // would unpark straight into an eviction.
                        l.last_heartbeat = NOW;
                    }
                }
                ReconcileStep::Add { slot, devices } => {
                    if !loads.iter().any(|l| l.id == slot) {
                        loads.push(ReplicaLoad {
                            id: slot,
                            devices,
                            occupancy: 0.0,
                            queue_depth: 0,
                            busy: false,
                            booting: false,
                            draining: false,
                            parked: false,
                            imbalance: 1.0,
                            last_heartbeat: NOW,
                            role: PoolRole::Unified,
                        });
                    }
                }
                ReconcileStep::Drain { replica } => {
                    if let Some(l) = loads
                        .iter_mut()
                        .find(|l| l.id == replica && !l.draining)
                    {
                        l.draining = true;
                    }
                }
                ReconcileStep::Rebalance { .. } => {}
                ReconcileStep::Evict { replica } => {
                    loads.retain(|l| l.id != replica);
                }
            }
        }
    }

    fn digest(loads: &[ReplicaLoad]) -> Vec<(usize, usize, bool, bool)> {
        let mut d: Vec<_> = loads
            .iter()
            .map(|l| (l.id, l.devices, l.parked, l.draining))
            .collect();
        d.sort_unstable();
        d
    }

    let rec = Reconciler::new(10.0);
    check("reconciler idempotent+monotone", 200, |rng: &mut Rng| {
        let (loads, spec) = random_state(rng);

        // Idempotence: the batch applied twice is the batch applied
        // once — every second application is all no-ops.
        let steps = rec.plan(&spec, &loads, NOW);
        let mut once = loads.clone();
        apply(&steps, &mut once);
        let mut twice = once.clone();
        apply(&steps, &mut twice);
        assert_eq!(
            digest(&once),
            digest(&twice),
            "replaying a step batch must not move the state"
        );

        // Monotonicity + bounded convergence: re-planning after each
        // application never grows drift, and drift hits zero within
        // the convergence bound.
        let mut state = loads;
        let mut prev = usize::MAX;
        for round in 0..CONVERGENCE_ROUNDS {
            let steps = rec.plan(&spec, &state, NOW);
            assert!(
                steps.len() <= prev,
                "round {round} drift grew: {} -> {} ({steps:?})",
                prev,
                steps.len()
            );
            prev = steps.len();
            if steps.is_empty() {
                return;
            }
            apply(&steps, &mut state);
        }
        let residual = rec.plan(&spec, &state, NOW);
        assert!(
            residual.is_empty(),
            "not converged within {CONVERGENCE_ROUNDS} rounds: {residual:?}"
        );
    });
}

/// For any random `(from.tp, from.dp)` x `(to.tp, to.dp)` pairing, the
/// KV migration planner's per-leg fabric splits sum *exactly* to the
/// leg's bytes (the byte-remainder regression), pair a device of the
/// source rank's TP group with one of the destination rank's group,
/// disposition every snapshot sequence exactly once, and never charge
/// more copy bytes than the budget allows.
#[test]
fn prop_kv_migration_fabric_legs_conserve_bytes_across_tp() {
    use elastic_moe::kvmigrate::{
        home_rank, plan_kv_migration, rank_devices, KvSeq, KvSnapshot,
        KvVerdict,
    };
    check("kv fabric legs", 150, |rng: &mut Rng| {
        let cost = CostModel::new(dsv2_lite(), Timings::cloudmatrix());
        let tps = [1usize, 2, 3, 4, 8];
        let from_tp = tps[rng.below(tps.len() as u64) as usize];
        let to_tp = tps[rng.below(tps.len() as u64) as usize];
        let from_dp = 1 + rng.below(3) as usize;
        let to_dp = 1 + rng.below(3) as usize;
        let from = ParallelConfig::standard(
            from_dp,
            from_tp,
            (0..from_dp * from_tp).collect(),
        )
        .unwrap();
        // Either a disjoint device pool (every sequence moves) or the
        // same pool (prefix groups may survive and remap in place).
        let base = if rng.bool(0.5) { 0 } else { 1000 };
        let to = ParallelConfig::standard(
            to_dp,
            to_tp,
            (base..base + to_dp * to_tp).collect(),
        )
        .unwrap();
        let block_tokens = 16;
        let n = 1 + rng.below(12) as usize;
        let seqs: Vec<KvSeq> = (0..n as u64)
            .map(|id| {
                let len = 64 + rng.below(6000) as usize;
                KvSeq {
                    id,
                    len,
                    blocks: len.div_ceil(block_tokens),
                    home_rank: home_rank(id, from_dp),
                }
            })
            .collect();
        let snap = KvSnapshot {
            block_tokens,
            seqs: seqs.clone(),
            from: from.clone(),
        };
        // Half the cases get an effectively unlimited budget, half a
        // tight one that forces recompute verdicts into the mix.
        let budget = if rng.bool(0.5) {
            16 << 30
        } else {
            rng.below(300) * (1 << 20)
        };
        let (plan, used) = plan_kv_migration(&snap, &to, &cost, budget);

        assert_eq!(
            plan.legs.len(),
            seqs.len(),
            "every sequence dispositioned exactly once"
        );
        assert!(
            plan.blocks_conserved(snap.total_blocks()),
            "block conservation at TP {from_tp}->{to_tp}"
        );
        assert!(used <= budget, "budget exceeded: {used} > {budget}");
        assert_eq!(used, plan.copied_bytes());

        let mut fabric_total = 0u64;
        for leg in &plan.legs {
            let splits = plan.fabric_legs(leg);
            match leg.verdict {
                KvVerdict::Copy { src_rank, dst_rank } => {
                    let bytes = leg.len as u64 * plan.bytes_per_token;
                    let sum: u64 =
                        splits.iter().map(|&(_, _, b)| b).sum();
                    assert_eq!(
                        sum, bytes,
                        "fabric split lost bytes at TP \
                         {from_tp}->{to_tp} (len {})",
                        leg.len
                    );
                    let srcs = rank_devices(&plan.from, src_rank);
                    let dsts = rank_devices(&plan.to, dst_rank);
                    assert_eq!(
                        splits.len(),
                        srcs.len().max(dsts.len()),
                        "one split per TP shard pair"
                    );
                    for &(s, d, b) in &splits {
                        assert!(
                            srcs.contains(&s),
                            "src device {s} outside source rank \
                             {src_rank} group {srcs:?}"
                        );
                        assert!(
                            dsts.contains(&d),
                            "dst device {d} outside target rank \
                             {dst_rank} group {dsts:?}"
                        );
                        assert!(b > 0, "zero-byte fabric leg");
                    }
                    fabric_total += sum;
                }
                _ => assert!(
                    splits.is_empty(),
                    "non-copy verdicts have no fabric legs"
                ),
            }
        }
        let transfer_total: u64 =
            plan.transfers().iter().map(|t| t.2).sum();
        assert_eq!(fabric_total, transfer_total);
        assert_eq!(fabric_total, plan.copied_bytes());
    });
}

/// The attainment accounting conservation law over real runs: in every
/// window of every per-tenant and per-pool series, `attained +
/// violated + in_flight == arrived`, and the tenant partition covers
/// every recorded request (`docs/architecture/11-reporting.md`).
#[test]
fn prop_attainment_windows_conserve_over_real_runs() {
    use elastic_moe::obs::attain;

    // Per tenant: the reconcile ledger leg (estimator, guards and the
    // duplicate-command fault all active).
    let (out, _) =
        elastic_moe::experiments::reconcile::ledger_run(7, true).unwrap();
    let slo = elastic_moe::experiments::reconcile::report_slo();
    let reqs = out.recorder.all();
    assert!(!reqs.is_empty());
    let by_tenant = attain::per_tenant(reqs, &slo, 15.0, out.end_time);
    let mut covered = 0usize;
    for (key, ws) in &by_tenant {
        for w in ws {
            assert!(
                w.conserves(),
                "{key} window [{}, {}) leaks arrivals",
                w.t0,
                w.t1
            );
        }
        covered += ws.iter().map(|w| w.arrived).sum::<usize>();
        let burn = attain::burn_rate(
            ws,
            slo.target_attainment,
            60.0,
            out.end_time,
        );
        assert!(burn >= 0.0 && burn.is_finite(), "{key} burn {burn}");
    }
    let in_range =
        reqs.iter().filter(|m| m.arrival < out.end_time).count();
    assert_eq!(covered, in_range, "tenant partition must cover arrivals");

    // Per pool: a disaggregated fleet cell, partitioned by KV-handoff
    // membership (prefill→decode vs served in place).
    let cells =
        elastic_moe::experiments::disagg::report_cells(7, true).unwrap();
    let slo = elastic_moe::experiments::disagg::report_slo();
    let cell = cells
        .iter()
        .find(|c| {
            c.out.trace.count(|e| {
                matches!(
                    e,
                    elastic_moe::chaos::TraceEvent::HandoffPlanned { .. }
                )
            }) > 0
        })
        .expect("a disagg cell plans prefill→decode handoffs");
    let handoff: std::collections::BTreeSet<u64> = cell
        .out
        .trace
        .events
        .iter()
        .filter_map(|e| match e {
            elastic_moe::chaos::TraceEvent::HandoffPlanned {
                id, ..
            } => Some(*id),
            _ => None,
        })
        .collect();
    let by_pool = attain::windows_by(
        cell.out.recorder.all(),
        &slo,
        15.0,
        cell.out.end_time,
        |m| {
            Some(if handoff.contains(&m.id) {
                "pool:prefill>decode".to_string()
            } else {
                "pool:local".to_string()
            })
        },
    );
    assert!(by_pool.contains_key("pool:prefill>decode"));
    for (key, ws) in &by_pool {
        for w in ws {
            assert!(
                w.conserves(),
                "{key} window [{}, {}) leaks arrivals",
                w.t0,
                w.t1
            );
        }
    }
}
