//! Seed-sweep determinism and golden-trace regression suite.
//!
//! Three contracts are pinned here, all riding on the event-driven
//! simulator core (`docs/architecture/07-event-core.md`):
//!
//! 1. **Determinism** — same seed ⇒ same run, bit for bit. Every
//!    conformance scenario (the chaos fault matrix and the live KV
//!    handoff) is run twice per seed across a sweep of seeds; the two
//!    runs must agree on `state_hash` (the FNV-1a digest folded over
//!    every state transition) and the trace invariant checkers must find
//!    zero violations at every seed, not just the experiments' default.
//! 2. **Telemetry neutrality** — enabling the observability registry
//!    (`docs/architecture/08-observability.md`) adds no queue events and
//!    feeds nothing back into simulation state, so each conformance cell
//!    produces a bit-identical `state_hash` with telemetry on and off.
//! 3. **Golden renderings** — the [`Trace`] JSON and the Chrome
//!    trace-event export are byte-stable. Hand-built canonical inputs
//!    are compared byte-for-byte against `rust/tests/golden/`. When an
//!    intentional format change lands, regenerate the golden files with
//!    `GOLDEN_BLESS=1 cargo test --test determinism golden` and commit
//!    the diff.
//!
//! The seed sweeps are split low/high so `cargo test` runs them on two
//! threads.

use elastic_moe::chaos::{FaultKind, PlanAudit, Trace, TraceEvent};
use elastic_moe::experiments::{chaos, disagg, kvmigrate, reconcile};
use elastic_moe::obs::export::chrome_trace;
use elastic_moe::obs::spans::{
    CAT_CONCURRENT, CAT_LIFECYCLE, CAT_SWITCHOVER,
};
use elastic_moe::obs::Telemetry;
use elastic_moe::tier::TierLevel;

/// Run the chaos conformance matrix twice per seed: zero invariant
/// violations everywhere, and the re-run reproduces every cell exactly —
/// `state_hash` first (the sensitive digest), then the full summary.
fn chaos_sweep(seeds: &[u64]) {
    for &seed in seeds {
        let a = chaos::conformance(seed).unwrap();
        let b = chaos::conformance(seed).unwrap();
        assert!(!a.is_empty(), "conformance matrix must be non-empty");
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(
                x.violations, 0,
                "seed {seed}: cell [{} × {} × {}] violated invariants \
                 (replay with `repro exp chaos --seed {seed}`)",
                x.method, x.direction, x.fault
            );
            assert_eq!(
                x.state_hash, y.state_hash,
                "seed {seed}: cell [{} × {} × {}] is nondeterministic — \
                 same-seed re-run changed the state hash",
                x.method, x.direction, x.fault
            );
            assert_eq!(x, y, "seed {seed}: re-run diverged beyond the hash");
        }
    }
}

/// Run the live KV-handoff conformance scenario (scale-up under the
/// migrating policy) twice per seed: deterministic digest, zero
/// violations, and the §4.4 zero-recompute claim at every seed.
fn kvmigrate_sweep(seeds: &[u64]) {
    for &seed in seeds {
        let a = kvmigrate::conformance_run(seed).unwrap();
        let b = kvmigrate::conformance_run(seed).unwrap();
        assert_eq!(
            a.violations, 0,
            "seed {seed}: live-handoff run violated trace invariants \
             (replay with `repro exp kvmigrate --seed {seed}`)"
        );
        assert_eq!(
            a.state_hash, b.state_hash,
            "seed {seed}: same-seed re-run changed the state hash"
        );
        assert_eq!(
            a.completed, b.completed,
            "seed {seed}: completion count diverged across re-runs"
        );
        assert!(a.completed > 0, "seed {seed}: nothing completed");
        // Scale-up under the migrating handoff is zero-recompute at
        // *every* seed: all device groups survive, so adoption is pure
        // remap.
        assert_eq!(a.handoff.recomputed, 0, "seed {seed}: restarts");
        assert_eq!(
            a.handoff.recompute_tokens, 0,
            "seed {seed}: recompute bill"
        );
    }
}

/// Run the control-plane reconcile matrix (fault-free plus heartbeat
/// loss, stale observed snapshot, duplicate command enactment) twice per
/// seed: zero violations — including the bounded-convergence invariant —
/// and a bit-identical `state_hash` on the re-run of every cell.
fn reconcile_sweep(seeds: &[u64]) {
    for &seed in seeds {
        let a = reconcile::conformance(seed).unwrap();
        let b = reconcile::conformance(seed).unwrap();
        assert!(!a.is_empty(), "reconcile matrix must be non-empty");
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(
                x.violations, 0,
                "seed {seed}: cell [{}] violated invariants (replay with \
                 `repro exp reconcile --seed {seed}`)",
                x.fault
            );
            assert_eq!(
                x.completed, x.arrived,
                "seed {seed}: cell [{}] lost requests",
                x.fault
            );
            assert_eq!(
                x.state_hash, y.state_hash,
                "seed {seed}: cell [{}] is nondeterministic — same-seed \
                 re-run changed the state hash",
                x.fault
            );
            assert_eq!(x, y, "seed {seed}: re-run diverged beyond the hash");
        }
    }
}

/// Run the prefill/decode disaggregation matrix (unified control,
/// happy-path handoff, severed-leg fault) twice per seed: zero
/// violations — including exactly-once handoff disposition over the new
/// legs — a zero-recompute happy path at every seed, and a
/// bit-identical `state_hash` on the re-run of every cell.
fn disagg_sweep(seeds: &[u64]) {
    for &seed in seeds {
        let a = disagg::conformance(seed).unwrap();
        let b = disagg::conformance(seed).unwrap();
        assert!(!a.is_empty(), "disagg matrix must be non-empty");
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(
                x.violations, 0,
                "seed {seed}: cell [{}] violated invariants (replay with \
                 `repro exp disagg --seed {seed}`)",
                x.cell
            );
            assert_eq!(
                x.completed, x.arrived,
                "seed {seed}: cell [{}] lost requests",
                x.cell
            );
            if x.cell == "disagg" {
                assert_eq!(
                    x.recomputed, 0,
                    "seed {seed}: happy-path handoff recomputed"
                );
                assert_eq!(
                    x.adopted, x.arrived,
                    "seed {seed}: not every sequence was adopted"
                );
            }
            assert_eq!(
                x.state_hash, y.state_hash,
                "seed {seed}: cell [{}] is nondeterministic — same-seed \
                 re-run changed the state hash",
                x.cell
            );
            assert_eq!(x, y, "seed {seed}: re-run diverged beyond the hash");
        }
    }
}

#[test]
fn chaos_conformance_is_deterministic_across_seeds_low() {
    chaos_sweep(&[5, 7, 11, 23]);
}

#[test]
fn chaos_conformance_is_deterministic_across_seeds_high() {
    chaos_sweep(&[42, 101, 137, 9001]);
}

#[test]
fn reconcile_conformance_is_deterministic_across_seeds_low() {
    reconcile_sweep(&[5, 7, 11, 23]);
}

#[test]
fn reconcile_conformance_is_deterministic_across_seeds_high() {
    reconcile_sweep(&[42, 101, 137, 9001]);
}

#[test]
fn disagg_conformance_is_deterministic_across_seeds_low() {
    disagg_sweep(&[5, 7, 11, 23]);
}

#[test]
fn disagg_conformance_is_deterministic_across_seeds_high() {
    disagg_sweep(&[42, 101, 137, 9001]);
}

#[test]
fn kvmigrate_conformance_is_deterministic_across_seeds_low() {
    kvmigrate_sweep(&[5, 7, 11, 23]);
}

#[test]
fn kvmigrate_conformance_is_deterministic_across_seeds_high() {
    kvmigrate_sweep(&[42, 101, 137, 9001]);
}

/// Telemetry neutrality across the chaos matrix: every conformance cell
/// hashes bit-identically with the registry enabled and disabled, at
/// every swept seed — enabling observability never changes a run.
#[test]
fn chaos_conformance_is_telemetry_neutral_across_seeds() {
    for seed in [7, 23, 9001] {
        let off = chaos::conformance_with_obs(seed, false).unwrap();
        let on = chaos::conformance_with_obs(seed, true).unwrap();
        assert_eq!(off.len(), on.len());
        for (x, y) in off.iter().zip(&on) {
            assert_eq!(
                x.state_hash, y.state_hash,
                "seed {seed}: cell [{} × {} × {}] changed its state hash \
                 when telemetry was enabled",
                x.method, x.direction, x.fault
            );
            assert_eq!(x, y, "seed {seed}: telemetry perturbed a cell");
        }
    }
}

/// Telemetry neutrality for the reconcile matrix: the reconciler spans
/// and the `fleet/spec_drift` series must be pure observers.
#[test]
fn reconcile_conformance_is_telemetry_neutral_across_seeds() {
    for seed in [7, 23] {
        let off = reconcile::conformance_with_obs(seed, false).unwrap();
        let on = reconcile::conformance_with_obs(seed, true).unwrap();
        assert_eq!(off.len(), on.len());
        for (x, y) in off.iter().zip(&on) {
            assert_eq!(
                x.state_hash, y.state_hash,
                "seed {seed}: cell [{}] changed its state hash when \
                 telemetry was enabled",
                x.fault
            );
            assert_eq!(x, y, "seed {seed}: telemetry perturbed a cell");
        }
    }
}

/// Telemetry neutrality for the disaggregation matrix: the handoff
/// counters (`handoffs_planned`, `handoff_bytes`, `handoff_adoptions`,
/// `handoff_recomputes`) must be pure observers of the pool handoff
/// path.
#[test]
fn disagg_conformance_is_telemetry_neutral_across_seeds() {
    for seed in [7, 23] {
        let off = disagg::conformance_with_obs(seed, false).unwrap();
        let on = disagg::conformance_with_obs(seed, true).unwrap();
        assert_eq!(off.len(), on.len());
        for (x, y) in off.iter().zip(&on) {
            assert_eq!(
                x.state_hash, y.state_hash,
                "seed {seed}: cell [{}] changed its state hash when \
                 telemetry was enabled",
                x.cell
            );
            assert_eq!(x, y, "seed {seed}: telemetry perturbed a cell");
        }
    }
}

/// Telemetry neutrality for the live KV-handoff scenario.
#[test]
fn kvmigrate_conformance_is_telemetry_neutral_across_seeds() {
    for seed in [7, 9001] {
        let off = kvmigrate::conformance_run_obs(seed, false).unwrap();
        let on = kvmigrate::conformance_run_obs(seed, true).unwrap();
        assert_eq!(
            off.state_hash, on.state_hash,
            "seed {seed}: live-handoff state hash changed when telemetry \
             was enabled"
        );
        assert_eq!(off.completed, on.completed);
        assert_eq!(off.violations, on.violations);
    }
}

/// The canonical golden trace: one small, hand-built run exercising every
/// [`TraceEvent`] variant — an aborted-and-rolled-back first event, a
/// completed second event with one remap adoption and one restart, a tier
/// shift with its audit point, and two finishes. Timestamps are halves so
/// the JSON number rendering is trivially stable.
fn canonical_trace() -> Trace {
    let mut tr = Trace::new();
    tr.push(TraceEvent::Arrival {
        t: 0.5,
        id: 1,
        tokens: 5000,
    });
    tr.push(TraceEvent::Arrival {
        t: 1.0,
        id: 2,
        tokens: 4000,
    });
    tr.push(TraceEvent::ScaleCommand {
        t: 2.0,
        event: 0,
        from_devices: 8,
        to_devices: 12,
        declared_pause: Some((2.5, 3.0)),
    });
    tr.push(TraceEvent::PlanAudited {
        t: 2.0,
        event: 0,
        audit: PlanAudit {
            snapshot_blocks: 10,
            kv_remapped_blocks: 6,
            kv_copied_blocks: 3,
            kv_freed_blocks: 1,
            kv_copied_bytes: 4096,
            migration_budget_bytes: 65536,
            expert_migration_bytes: 32768,
        },
    });
    tr.push(TraceEvent::IntakePaused { t: 2.5, event: 0 });
    tr.push(TraceEvent::Suspended {
        t: 2.5,
        event: 0,
        id: 1,
    });
    tr.push(TraceEvent::FaultFired {
        t: 2.5,
        event: 0,
        fault: FaultKind::P2pLinkFail { after_legs: 2 },
    });
    tr.push(TraceEvent::Resumed {
        t: 3.0,
        event: 0,
        id: 1,
    });
    tr.push(TraceEvent::ScaleAborted {
        t: 3.0,
        event: 0,
        rolled_back: true,
        reason: "p2p link failed on leg 2".to_string(),
    });
    tr.push(TraceEvent::IntakeResumed { t: 3.0, event: 0 });
    tr.push(TraceEvent::ScaleCommand {
        t: 4.0,
        event: 1,
        from_devices: 8,
        to_devices: 12,
        declared_pause: None,
    });
    tr.push(TraceEvent::Suspended {
        t: 4.5,
        event: 1,
        id: 2,
    });
    tr.push(TraceEvent::Adopted {
        t: 5.0,
        event: 1,
        id: 1,
        remap: true,
    });
    tr.push(TraceEvent::Restarted {
        t: 5.0,
        event: 1,
        id: 2,
    });
    tr.push(TraceEvent::ScaleCompleted {
        t: 5.5,
        event: 1,
        devices: 12,
    });
    tr.push(TraceEvent::TierShift {
        t: 6.0,
        replica: 0,
        tag: "layer0.experts".to_string(),
        bytes: 1048576,
        from: TierLevel::Hbm,
        to: TierLevel::HostDram,
    });
    tr.push(TraceEvent::TierAudit {
        t: 6.5,
        replica: 0,
        dram_bytes: 1048576,
    });
    tr.push(TraceEvent::Finished {
        t: 7.0,
        id: 1,
        tokens: 200,
    });
    tr.push(TraceEvent::Finished {
        t: 7.5,
        id: 2,
        tokens: 150,
    });
    tr.push(TraceEvent::SpecDeclared {
        t: 8.0,
        replicas: 2,
        devices: 6,
        parked: 0,
        drift: 1,
    });
    tr.push(TraceEvent::ReconcileStep {
        t: 8.0,
        replica: 1,
        step: "resize->4".to_string(),
        applied: true,
    });
    tr.push(TraceEvent::HeartbeatMissed { t: 8.5, replica: 1 });
    tr.push(TraceEvent::ReplicaEvicted {
        t: 9.0,
        replica: 1,
        requeued: 3,
    });
    tr
}

/// Byte-for-byte regression against the committed golden file. Bless a
/// deliberate format change with
/// `GOLDEN_BLESS=1 cargo test --test determinism golden`.
#[test]
fn golden_trace_file_is_byte_stable() {
    let rendered = format!("{}\n", canonical_trace().to_json());
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/golden/trace.json");
    if std::env::var_os("GOLDEN_BLESS").is_some() {
        std::fs::write(&path, rendered.as_bytes()).unwrap();
        return;
    }
    let golden = std::fs::read(&path).unwrap_or_else(|e| {
        panic!(
            "missing golden file {}: {e} — regenerate with \
             `GOLDEN_BLESS=1 cargo test --test determinism golden`",
            path.display()
        )
    });
    assert!(
        rendered.as_bytes() == golden.as_slice(),
        "golden trace drifted from {}; if the serialization change is \
         intentional, regenerate with `GOLDEN_BLESS=1 cargo test --test \
         determinism golden` and commit the diff",
        path.display()
    );
}

/// Canonical telemetry for the Chrome-trace golden: two replicas, a
/// concurrent + switchover span pair on one scaling event, a lifecycle
/// boot, one instant mark, and a cluster plus a per-replica counter
/// series. Timestamps are halves so the µs scaling renders integral.
fn canonical_telemetry() -> Telemetry {
    let mut t = Telemetry::new();
    t.record_series("pool/devices_free", 0.0, 6.0);
    t.record_series("pool/devices_free", 4.0, 2.0);
    t.record_series("replica0/queue_depth", 0.0, 2.0);
    t.record_series("replica0/queue_depth", 1.0, 4.0);
    t.spans
        .span(0, Some(0), "scale0/warmup", CAT_CONCURRENT, 1.0, 2.5);
    t.spans.span(
        0,
        Some(0),
        "scale0/switchover",
        CAT_SWITCHOVER,
        2.5,
        3.0,
    );
    t.spans.span(1, None, "cold_boot", CAT_LIFECYCLE, 0.5, 1.5);
    t.spans.instant(0, "fault", 2.0);
    t
}

/// Byte-for-byte regression of the Chrome trace-event export against
/// `rust/tests/golden/chrome_trace.json`. Bless a deliberate exporter
/// change with `GOLDEN_BLESS=1 cargo test --test determinism golden`.
#[test]
fn golden_chrome_trace_is_byte_stable() {
    let rendered = format!("{}\n", chrome_trace(&canonical_telemetry()));
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/golden/chrome_trace.json");
    if std::env::var_os("GOLDEN_BLESS").is_some() {
        std::fs::write(&path, rendered.as_bytes()).unwrap();
        return;
    }
    let golden = std::fs::read(&path).unwrap_or_else(|e| {
        panic!(
            "missing golden file {}: {e} — regenerate with \
             `GOLDEN_BLESS=1 cargo test --test determinism golden`",
            path.display()
        )
    });
    assert!(
        rendered.as_bytes() == golden.as_slice(),
        "golden Chrome trace drifted from {}; if the exporter change is \
         intentional, regenerate with `GOLDEN_BLESS=1 cargo test --test \
         determinism golden` and commit the diff",
        path.display()
    );
}

/// The golden rendering parses back, carries one object per event, covers
/// the full event taxonomy, and embeds the trace's own digest as the hex
/// `state_hash` field.
#[test]
fn golden_trace_roundtrips_and_embeds_its_digest() {
    let tr = canonical_trace();
    let text = tr.to_json().to_string();
    let parsed = elastic_moe::util::json::parse(&text).unwrap();
    let events = parsed.get("events").as_arr().unwrap();
    assert_eq!(events.len(), tr.len());
    assert_eq!(
        parsed.get("state_hash").as_str().unwrap(),
        format!("{:016x}", tr.state_hash())
    );
    for kind in [
        "arrival",
        "scale_command",
        "plan_audited",
        "fault_fired",
        "intake_paused",
        "intake_resumed",
        "suspended",
        "resumed",
        "adopted",
        "restarted",
        "scale_completed",
        "scale_aborted",
        "finished",
        "tier_shift",
        "tier_audit",
        "spec_declared",
        "reconcile_step",
        "heartbeat_missed",
        "replica_evicted",
    ] {
        assert!(
            events.iter().any(|e| e.get("ev").as_str() == Some(kind)),
            "canonical trace must cover TraceEvent kind '{kind}'"
        );
    }
}

/// Byte-for-byte regression of the `repro report` renderer against
/// `rust/tests/golden/report.md`, over the hand-built canonical input
/// ([`elastic_moe::report::sample_input`]). Bless a deliberate format
/// change with `GOLDEN_BLESS=1 cargo test --test determinism golden`.
#[test]
fn golden_report_is_byte_stable() {
    let rendered =
        elastic_moe::report::render(&elastic_moe::report::sample_input());
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/golden/report.md");
    if std::env::var_os("GOLDEN_BLESS").is_some() {
        std::fs::write(&path, rendered.as_bytes()).unwrap();
        return;
    }
    let golden = std::fs::read(&path).unwrap_or_else(|e| {
        panic!(
            "missing golden file {}: {e} — regenerate with \
             `GOLDEN_BLESS=1 cargo test --test determinism golden`",
            path.display()
        )
    });
    assert!(
        rendered.as_bytes() == golden.as_slice(),
        "golden report drifted from {}; if the format change is \
         intentional, regenerate with `GOLDEN_BLESS=1 cargo test --test \
         determinism golden` and commit the diff",
        path.display()
    );
}

/// `repro report` is byte-deterministic: generating the chaos report
/// twice from the same seed yields identical markdown, and that
/// markdown carries every section the postmortem contract promises —
/// the concurrent-vs-switchover cost table, a device-second-annotated
/// scaling event in the attainment timeline, the decision ledger with
/// its guard-vetoed (checked no-op) entries, and a fault cell's replay
/// bundle.
#[test]
fn report_output_is_bit_identical_and_complete() {
    let a = elastic_moe::report::generate("chaos", 23, true).unwrap();
    let b = elastic_moe::report::generate("chaos", 23, true).unwrap();
    assert_eq!(a, b, "same seed must render identical report bytes");
    for needle in [
        "### Scaling events — concurrent vs switchover",
        "### Attainment timeline",
        " dev-s)",
        "## Decision ledger",
        "### Reconciler guard no-ops",
        "### Postmortem",
        "Replay bundle:",
        "```json",
    ] {
        assert!(a.contains(needle), "report misses {needle:?}");
    }
}

/// `DecisionExplain` emission is unconditional — never gated on the
/// telemetry registry — so the ledger leg's `state_hash` (which folds
/// the trace, explains included) is bit-identical with observability
/// on and off.
#[test]
fn decision_explains_are_telemetry_neutral() {
    let is_explain =
        |e: &TraceEvent| matches!(e, TraceEvent::DecisionExplain { .. });
    let (on, v_on) = reconcile::ledger_run_obs(23, true, true).unwrap();
    let (off, v_off) = reconcile::ledger_run_obs(23, true, false).unwrap();
    assert_eq!(on.state_hash, off.state_hash, "telemetry changed the run");
    assert!(on.telemetry.is_some());
    assert!(off.telemetry.is_none());
    assert_eq!(v_on.len(), v_off.len());
    let n = on.trace.count(is_explain);
    assert!(n > 0, "policy ticks must emit explain records");
    assert_eq!(off.trace.count(is_explain), n);
}
