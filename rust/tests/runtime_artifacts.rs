//! Integration tests: the Rust runtime loads the real AOT artifacts,
//! executes them via PJRT, and matches independent Rust-side references.
//!
//! Requires `make artifacts` to have been run; tests no-op (with a notice)
//! otherwise so `cargo test` stays green on a fresh checkout.

use std::path::PathBuf;

use elastic_moe::runtime::{weights, HostTensor, Manifest, Pjrt};

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn runtime() -> Option<Pjrt> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Pjrt::load(Manifest::load(dir).unwrap()).unwrap())
}

/// Plain f32 matmul reference: [m,k] x [k,n].
fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for p in 0..k {
            let av = a[i * k + p];
            for j in 0..n {
                out[i * n + j] += av * b[p * n + j];
            }
        }
    }
    out
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

#[test]
fn embed_decode_matches_rows() {
    let Some(rt) = runtime() else { return };
    let m = rt.manifest().clone();
    let emb =
        weights::load_weight(&m.dir, m.weight("emb").unwrap(), false).unwrap();
    let b = m.model.batch;
    let ids: Vec<i32> = (0..b as i32).map(|i| i * 7 + 3).collect();
    let out = rt
        .run(
            "embed_decode",
            &[emb.clone(), HostTensor::i32(vec![b], ids.clone())],
        )
        .unwrap();
    assert_eq!(out.len(), 1);
    let x = out[0].as_f32().unwrap();
    let d = m.model.d_model;
    let table = emb.as_f32().unwrap();
    for (row, &id) in ids.iter().enumerate() {
        let got = &x[row * d..(row + 1) * d];
        let want = &table[id as usize * d..(id as usize + 1) * d];
        assert_eq!(got, want, "row {row}");
    }
}

#[test]
fn expert_ffn_matches_rust_reference() {
    let Some(rt) = runtime() else { return };
    let m = rt.manifest().clone();
    let (b, d, f) = (m.model.batch, m.model.d_model, m.model.d_ff);
    let w1 = weights::load_weight(&m.dir, m.weight("layer0.w1.e0").unwrap(), false)
        .unwrap();
    let w3 = weights::load_weight(&m.dir, m.weight("layer0.w3.e0").unwrap(), false)
        .unwrap();
    let w2 = weights::load_weight(&m.dir, m.weight("layer0.w2.e0").unwrap(), false)
        .unwrap();
    let x: Vec<f32> =
        (0..b * d).map(|i| ((i % 17) as f32 - 8.0) * 0.05).collect();

    let out = rt
        .run(
            "expert_ffn_decode",
            &[
                HostTensor::f32(vec![b, d], x.clone()),
                w1.clone(),
                w3.clone(),
                w2.clone(),
            ],
        )
        .unwrap();
    let got = out[0].as_f32().unwrap();

    // Independent Rust-side SwiGLU: (silu(x@w1) * (x@w3)) @ w2
    let h1 = matmul(&x, w1.as_f32().unwrap(), b, d, f);
    let h3 = matmul(&x, w3.as_f32().unwrap(), b, d, f);
    let h: Vec<f32> =
        h1.iter().zip(&h3).map(|(a, c)| silu(*a) * c).collect();
    let want = matmul(&h, w2.as_f32().unwrap(), b, f, d);
    let max_diff = got
        .iter()
        .zip(&want)
        .map(|(g, w)| (g - w).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-3, "max diff {max_diff}");
}

#[test]
fn artifact_shape_validation_rejects_bad_args() {
    let Some(rt) = runtime() else { return };
    let m = rt.manifest().clone();
    let b = m.model.batch;
    // Wrong arg count
    assert!(rt.run("embed_decode", &[]).is_err());
    // Wrong shape
    let emb = HostTensor::zeros_f32(vec![3, 3]);
    let ids = HostTensor::i32(vec![b], vec![0; b]);
    assert!(rt.run("embed_decode", &[emb, ids]).is_err());
}

#[test]
fn executable_cache_compiles_once() {
    let Some(rt) = runtime() else { return };
    assert_eq!(rt.compiled_count(), 0);
    rt.executable("final_logits").unwrap();
    rt.executable("final_logits").unwrap();
    assert_eq!(rt.compiled_count(), 1);
}

#[test]
fn buffer_execution_matches_literal_execution() {
    let Some(rt) = runtime() else { return };
    let m = rt.manifest().clone();
    let (b, d) = (m.model.batch, m.model.d_model);
    let emb =
        weights::load_weight(&m.dir, m.weight("emb").unwrap(), false).unwrap();
    let lnf =
        weights::load_weight(&m.dir, m.weight("ln_f").unwrap(), false).unwrap();
    let x = HostTensor::f32(
        vec![b, d],
        (0..b * d).map(|i| (i as f32).sin() * 0.1).collect(),
    );
    let via_literal = rt
        .run("final_logits", &[x.clone(), lnf.clone(), emb.clone()])
        .unwrap();
    // Device-resident path: weights uploaded once ("zero-copy handle").
    let xb = rt.upload(&x).unwrap();
    let lb = rt.upload(&lnf).unwrap();
    let eb = rt.upload(&emb).unwrap();
    let via_buffer = rt.run_b("final_logits", &[&xb, &lb, &eb]).unwrap();
    let diff = via_literal[0].max_abs_diff(&via_buffer[0]).unwrap();
    assert!(diff < 1e-6, "literal vs buffer diff {diff}");
}

#[test]
fn decode_step_full_runs_and_is_finite() {
    let Some(rt) = runtime() else { return };
    let m = rt.manifest().clone();
    let md = m.model.clone();
    let (b, s, h, dh) = (md.batch, md.max_seq, md.n_heads, md.head_dim);
    let mut args: Vec<HostTensor> = Vec::new();
    args.push(HostTensor::i32(vec![b], vec![1; b]));
    args.push(HostTensor::i32(vec![b], vec![1; b])); // lens=1: first token
    for _ in 0..2 * md.n_layers {
        args.push(HostTensor::zeros_f32(vec![b, s, h, dh]));
    }
    for w in ["emb", "ln_f"] {
        args.push(
            weights::load_weight(&m.dir, m.weight(w).unwrap(), false).unwrap(),
        );
    }
    for li in 0..md.n_layers {
        for t in m.layer_tensors.clone() {
            if matches!(t.as_str(), "w1" | "w3" | "w2") {
                // Reassemble the stacked expert tensor from per-expert files.
                let mut stacked: Vec<f32> = Vec::new();
                let mut shape = Vec::new();
                for e in 0..md.n_experts {
                    let spec =
                        m.weight(&format!("layer{li}.{t}.e{e}")).unwrap();
                    let w =
                        weights::load_weight(&m.dir, spec, false).unwrap();
                    if shape.is_empty() {
                        shape = vec![md.n_experts];
                        shape.extend_from_slice(w.shape());
                    }
                    stacked.extend_from_slice(w.as_f32().unwrap());
                }
                args.push(HostTensor::f32(shape, stacked));
            } else {
                let spec = m.weight(&format!("layer{li}.{t}")).unwrap();
                args.push(weights::load_weight(&m.dir, spec, false).unwrap());
            }
        }
    }
    let out = rt.run("decode_step_full", &args).unwrap();
    assert_eq!(out.len(), 1 + 2 * md.n_layers);
    let logits = out[0].as_f32().unwrap();
    assert_eq!(out[0].shape(), &[b, md.vocab]);
    assert!(logits.iter().all(|v| v.is_finite()));
}
