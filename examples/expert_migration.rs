//! Virtual-page expert migration walkthrough (§4.6 / Appendix D.5): shows
//! the EP4 -> EP6 remapping of DSv2-Lite's 64 experts — what moves, what is
//! reused, the page-table state before/after, and the O(1)-remap vs
//! realloc-copy cost asymmetry.
//!
//! Run: `cargo run --release --example expert_migration`

use std::cell::RefCell;
use std::rc::Rc;

use anyhow::Result;

use elastic_moe::config::model::dsv2_lite;
use elastic_moe::config::ParallelConfig;
use elastic_moe::device::{Cluster, Timings};
use elastic_moe::hmm::control::{HmmControl, HmmOptions};
use elastic_moe::hmm::PlanOp;
use elastic_moe::util::fmt_bytes;

fn print_placement(hmm: &HmmControl, devices: usize, layer: usize) {
    println!("  layer {layer} expert placement (vpage tables):");
    for d in 0..devices {
        if let Some(w) = hmm.worker(d) {
            let experts = w.vpages.experts(layer);
            if !experts.is_empty() {
                println!(
                    "    dev{d}: {} experts {:?}{}",
                    experts.len(),
                    &experts[..experts.len().min(8)],
                    if experts.len() > 8 { " …" } else { "" }
                );
            }
        }
    }
}

fn main() -> Result<()> {
    elastic_moe::util::logging::init();
    let model = dsv2_lite();
    let cluster = Rc::new(RefCell::new(Cluster::cloudmatrix(6)));
    let mut hmm = HmmControl::new(
        cluster.clone(),
        model.clone(),
        HmmOptions::default(),
    );

    let p4 = ParallelConfig::standard(2, 2, (0..4).collect())?;
    let p6 = ParallelConfig::standard(3, 2, (0..6).collect())?;
    println!(
        "model {}: {} experts x {} layers, {} per expert\n",
        model.name,
        model.n_experts,
        model.n_layers,
        fmt_bytes(model.expert_bytes())
    );
    hmm.load_initial(&p4, 8 << 30)?;
    println!("== before: {} ==", p4.label());
    print_placement(&hmm, 6, 0);

    let plan = hmm.plan_scale(&p6)?;
    println!("\n== plan {} -> {} ==", plan.from_label, plan.to_label);
    println!(
        "  zero-copy reused : {} ({:.1}% of weight bytes)",
        fmt_bytes(plan.reused_bytes()),
        plan.reuse_fraction() * 100.0
    );
    println!(
        "  P2P transferred  : {} in {} expert migrations + attn shards",
        fmt_bytes(plan.p2p_bytes()),
        plan.migrated_expert_count()
    );
    // Sample of planned ops for layer 0.
    println!("  layer-0 migrations:");
    for op in plan.ops.iter().filter(|op| {
        matches!(op, PlanOp::MigrateExpert { layer: 0, .. })
    }) {
        if let PlanOp::MigrateExpert {
            expert, src, dst, ..
        } = op
        {
            println!("    expert {expert:>2}: dev{src} → dev{dst}");
        }
    }

    let stats = hmm.execute_plan(&plan, &p6)?.stats;
    println!("\n== executed (simulated stage times) ==");
    println!("  attn P2P        : {:.3} s", stats.attn_p2p_time);
    println!("  expert P2P      : {:.3} s", stats.expert_p2p_time);
    println!("  vpage remaps    : {:.4} s (O(1) per expert)", stats.remap_time);
    println!("  KV init (new)   : {:.3} s", stats.kv_init_time);
    let t = Timings::cloudmatrix();
    let per_dev_expert_bytes =
        (model.n_experts / 6 + 1) * model.n_layers * model.expert_bytes();
    println!(
        "  [contrast] realloc-copy path would cost ~{:.2} s per device and \
         transiently double {} of expert memory",
        t.realloc_copy(per_dev_expert_bytes),
        fmt_bytes(per_dev_expert_bytes),
    );

    println!("\n== after: {} ==", p6.label());
    print_placement(&hmm, 6, 0);
    println!(
        "\n  deferred frees pending: {} (old pages stay mapped until the \
         old instance drains)",
        hmm.deferred_free_count()
    );
    let n = hmm.apply_deferred_frees()?;
    println!("  switchover complete: {n} orphaned expert pages released");
    Ok(())
}
