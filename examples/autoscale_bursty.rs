//! SLO-driven autoscaling under a bursty trace: the "10x within minutes"
//! pattern of §2.2. The Coordinator's load estimator triggers elastic
//! scale-ups during the burst and scales back down afterwards; the example
//! prints the device/SLO timeline.
//!
//! Run: `cargo run --release --example autoscale_bursty`

use anyhow::Result;

use elastic_moe::config::model::dsv2_lite;
use elastic_moe::config::{ParallelConfig, SloConfig};
use elastic_moe::coordinator::{LoadEstimator, ServingSim, Trigger};
use elastic_moe::device::Timings;
use elastic_moe::engine::CostModel;
use elastic_moe::experiments::common::make_method;
use elastic_moe::workload::{RateProfile, WorkloadGen, WorkloadSpec};

fn main() -> Result<()> {
    elastic_moe::util::logging::init();
    let model = dsv2_lite();
    let tp = model.tp;
    let slo = SloConfig::new(3.0, 1.0);
    let cost = CostModel::new(model.clone(), Timings::cloudmatrix());

    // Burst: 4x the base rate for 2 minutes in the middle of a 8-minute
    // trace.
    let base = 6.0;
    let mut gen = WorkloadGen::new(WorkloadSpec {
        prompt_len: 2000,
        decode_min: 150,
        decode_max: 250,
        profile: RateProfile::Burst {
            base,
            factor: 5.0,
            start: 120.0,
            len: 120.0,
        },
        seed: 9,
    });
    let horizon = 480.0;
    let arrivals = gen.arrivals_until(horizon);
    println!(
        "bursty trace: {} requests, {base} rps base, 5x burst at t=120..240",
        arrivals.len()
    );

    let mut method = make_method("elastic", &model, 12)?;
    let mut estimator = LoadEstimator::new(slo);
    estimator.cooldown = 20.0;
    estimator.up_patience = 1;
    estimator.down_patience = 8;

    let step = move |p: &ParallelConfig, delta: isize| {
        let n = (p.n_devices() as isize + delta * tp as isize).max(0) as usize;
        if n == 0 || n > 12 {
            return None;
        }
        ParallelConfig::standard(n / tp, tp, (0..n).collect()).ok()
    };
    let trigger = Trigger::Auto {
        estimator,
        up: Box::new(move |p| step(p, 1)),
        down: Box::new(move |p| step(p, -1)),
    };

    let sim = ServingSim::new(cost, slo);
    let initial = ParallelConfig::standard(2, tp, (0..4).collect())?;
    let out = sim.run(method.as_mut(), &initial, arrivals, trigger, horizon)?;

    println!("\ntime   devices  SLO%(arrivals in bucket)");
    let bucket = 30.0;
    let mut t = 0.0;
    while t < horizon {
        let att = out.recorder.attainment_by_arrival(t, t + bucket, &slo);
        let devs = out
            .device_timeline
            .iter()
            .rev()
            .find(|(at, _)| *at <= t + bucket)
            .map(|(_, n)| *n)
            .unwrap_or(4);
        println!(
            "{:>5.0}  {:^7}  {}",
            t,
            devs,
            if att.is_nan() {
                "-".to_string()
            } else {
                format!("{:.1}%", att * 100.0)
            }
        );
        t += bucket;
    }

    println!("\nscaling events:");
    for ev in &out.scaling_events {
        println!(
            "  {}: {:.2}s latency, {:.2}s downtime",
            ev.metrics.label(),
            ev.ready_after,
            ev.metrics.downtime
        );
    }
    let w = out.recorder.window(0.0, out.end_time + 1e-6, &slo);
    println!(
        "\noverall: {} completed, SLO attainment {:.1}%, devices now {}",
        w.completed,
        w.slo_attainment * 100.0,
        out.device_timeline.last().unwrap().1
    );
    Ok(())
}
