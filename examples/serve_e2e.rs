//! End-to-end validation (DESIGN.md §2, EXPERIMENTS.md §E2E): serve real
//! batched requests through the full stack — AOT-compiled JAX/Pallas
//! artifacts executed via PJRT, weights owned by the HMM on simulated
//! devices, EP routing by the Rust engine — and perform a **live elastic
//! scale-up with expert migration in the middle of decoding**, verifying
//! the generated tokens are bit-identical to an unscaled reference run.
//!
//! Requires `make artifacts`. Run:
//! `cargo run --release --example serve_e2e`

use std::cell::RefCell;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{Context, Result};

use elastic_moe::config::{model, ParallelConfig};
use elastic_moe::device::Cluster;
use elastic_moe::engine::pjrt::PjrtBackend;
use elastic_moe::engine::{BatcherConfig, PagedKv, ServeEngine};
use elastic_moe::hmm::control::{HmmControl, HmmOptions, PayloadLoader};
use elastic_moe::hmm::weights::UnitKind;
use elastic_moe::runtime::{weights, HostTensor, Manifest, Pjrt};
use elastic_moe::sim::RealClock;
use elastic_moe::util::rng::Rng;
use elastic_moe::workload::Request;

fn make_loader(manifest: Manifest) -> PayloadLoader {
    Box::new(move |unit, _tp_rank| {
        let names: Vec<String> = match unit.kind {
            UnitKind::Embed => vec!["emb".into(), "ln_f".into()],
            UnitKind::Attn { layer } => {
                ["ln1", "wq", "wk", "wv", "wo", "ln2", "w_gate"]
                    .iter()
                    .map(|t| format!("layer{layer}.{t}"))
                    .collect()
            }
            UnitKind::Expert { layer, expert } => vec![
                format!("layer{layer}.w1.e{expert}"),
                format!("layer{layer}.w3.e{expert}"),
                format!("layer{layer}.w2.e{expert}"),
            ],
            UnitKind::SharedExpert { .. } => return None,
        };
        let tensors: Option<Vec<HostTensor>> = names
            .iter()
            .map(|n| {
                manifest.weight(n).ok().and_then(|s| {
                    weights::load_weight(&manifest.dir, s, true).ok()
                })
            })
            .collect();
        tensors.map(Rc::new)
    })
}

fn requests(md: &elastic_moe::runtime::ModelDims, decode: usize) -> Vec<Request> {
    let mut rng = Rng::new(2026);
    (0..md.batch as u64)
        .map(|i| {
            let plen = rng.range(md.prefill_len as u64 / 2, md.prefill_len as u64)
                as usize;
            let mut r = Request::new(i + 1, 0.0, plen, decode);
            r.prompt_ids = (0..plen)
                .map(|_| rng.below(md.vocab as u64) as i32)
                .collect();
            r
        })
        .collect()
}

struct Deployment {
    hmm: Rc<RefCell<HmmControl>>,
    rt: Rc<Pjrt>,
}

fn engine_for(
    dep: &Deployment,
    binding: elastic_moe::hmm::control::InstanceBinding,
) -> Result<ServeEngine> {
    let md = dep.rt.manifest().model.clone();
    let backend = PjrtBackend::new(dep.rt.clone(), dep.hmm.clone(), binding)?;
    Ok(ServeEngine::new(
        BatcherConfig {
            max_batch: md.batch,
            max_prefill_tokens: md.batch * md.prefill_len,
        },
        PagedKv::new(4096, 16),
        Box::new(backend),
    ))
}

fn main() -> Result<()> {
    elastic_moe::util::logging::init();
    let manifest = Manifest::load("artifacts")
        .context("run `make artifacts` first")?;
    let md = manifest.model.clone();
    println!(
        "e2e model: {} ({:.1}M params, {} experts, top-{}, batch {})",
        md.name,
        md.param_count as f64 / 1e6,
        md.n_experts,
        md.top_k,
        md.batch
    );
    let rt = Rc::new(Pjrt::load(manifest.clone())?);

    // ---- boot: DP2-TP1-EP2 on devices {0,1} of a 4-device cluster ------
    let cluster = Rc::new(RefCell::new(Cluster::cloudmatrix(4)));
    let mut hmm =
        HmmControl::new(cluster, model::e2e(), HmmOptions::default());
    hmm.set_loader(make_loader(manifest.clone()));
    let p2 = ParallelConfig::standard(2, 1, vec![0, 1])?;
    let t_load = Instant::now();
    hmm.load_initial(&p2, 64 << 20)?;
    let proc = hmm.alloc_proc();
    let (binding, _) = hmm.attach_instance(proc)?;
    println!(
        "booted {} on 2 simulated NPUs in {:.2}s (weights loaded once, \
         zero-copy attached)",
        p2.label(),
        t_load.elapsed().as_secs_f64()
    );
    let dep = Deployment {
        hmm: Rc::new(RefCell::new(hmm)),
        rt,
    };
    let mut engine = engine_for(&dep, binding)?;

    // ---- reference run (no scaling) for the numerics check -------------
    let decode_len = 24;
    let reqs = requests(&md, decode_len);
    let clock = RealClock::new();
    let mut reference = Vec::new();
    {
        let (ref_binding, _) =
            dep.hmm.borrow_mut().attach_instance(9999)?;
        let mut ref_engine = engine_for(&dep, ref_binding)?;
        for r in reqs.clone() {
            ref_engine.submit(r);
        }
        while ref_engine.has_work() {
            let out = ref_engine.step(&clock)?;
            reference.extend(out.finished);
        }
        reference.sort_by_key(|r| r.id);
        dep.hmm.borrow_mut().detach_instance(9999)?;
    }

    // ---- live run: scale 2 -> 4 devices MID-DECODE ----------------------
    let mut live = Vec::new();
    for r in reqs.clone() {
        engine.submit(r);
    }
    let t0 = Instant::now();
    let mut steps = 0usize;
    let mut scaled = false;
    let mut scale_wall = 0.0f64;
    while engine.has_work() {
        let out = engine.step(&clock)?;
        live.extend(out.finished);
        steps += 1;
        if steps == 6 && !scaled {
            // Elastic scale-up while requests are mid-decode: the HMM
            // migrates experts to devices {2,3} with real payload moves;
            // the backend rebinds; the engine-held KV caches are untouched
            // (zero-copy reuse).
            let t_scale = Instant::now();
            let p4 = ParallelConfig::standard(4, 1, vec![0, 1, 2, 3])?;
            let (plan, stats, new_binding) = {
                let mut hmm = dep.hmm.borrow_mut();
                let plan = hmm.plan_scale(&p4)?;
                let stats = hmm.execute_plan(&plan, &p4)?.stats;
                let proc = hmm.alloc_proc();
                let (b, _) = hmm.attach_instance(proc)?;
                (plan, stats, b)
            };
            engine
                .backend_as_pjrt()
                .context("pjrt backend")?
                .rebind(new_binding)?;
            dep.hmm.borrow_mut().apply_deferred_frees()?;
            scale_wall = t_scale.elapsed().as_secs_f64();
            println!(
                "live scale-up 2→4 at decode step {steps}: {} experts \
                 migrated, {} bytes over fabric, sim stage time {:.3}s, \
                 wall {:.3}s — zero downtime (decode continues)",
                plan.migrated_expert_count(),
                plan.p2p_bytes(),
                stats.total,
                scale_wall,
            );
            scaled = true;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    live.sort_by_key(|r| r.id);

    // ---- verify + report -------------------------------------------------
    assert_eq!(live.len(), reference.len());
    for (a, b) in live.iter().zip(&reference) {
        assert_eq!(
            a.output_ids, b.output_ids,
            "request {}: tokens diverged after live migration!",
            a.id
        );
    }
    let total_tokens: usize = live.iter().map(|r| r.generated).sum();
    let ttfts: Vec<f64> = live.iter().filter_map(|r| r.ttft()).collect();
    let tpots: Vec<f64> = live.iter().filter_map(|r| r.tpot()).collect();
    println!("\n== end-to-end results (real PJRT compute, wall time) ==");
    println!("  requests        : {}", live.len());
    println!("  tokens generated: {total_tokens}");
    println!("  wall time       : {wall:.2} s");
    println!(
        "  throughput      : {:.1} tok/s, {:.2} req/s",
        total_tokens as f64 / wall,
        live.len() as f64 / wall
    );
    println!(
        "  TTFT mean       : {:.3} s   TPOT mean: {:.4} s",
        elastic_moe::util::stats::mean(&ttfts),
        elastic_moe::util::stats::mean(&tpots)
    );
    println!("  scale-up wall   : {scale_wall:.3} s (mid-decode)");
    println!(
        "\ntokens bit-identical to unscaled reference across live expert \
         migration ✓"
    );
    Ok(())
}
