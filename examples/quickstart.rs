//! Quickstart: boot a 4-NPU ElasticMoE deployment on the simulated
//! cluster, serve traffic, perform one zero-downtime scale-up to 6 NPUs,
//! and print the scaling metrics the paper reports.
//!
//! Run: `cargo run --release --example quickstart`

use anyhow::Result;

use elastic_moe::config::model::dsv2_lite;
use elastic_moe::config::{ParallelConfig, SloConfig};
use elastic_moe::coordinator::{ServingSim, Trigger};
use elastic_moe::device::Timings;
use elastic_moe::engine::CostModel;
use elastic_moe::experiments::common::make_method;
use elastic_moe::workload::{RateProfile, WorkloadGen, WorkloadSpec};

fn main() -> Result<()> {
    elastic_moe::util::logging::init();
    let model = dsv2_lite();
    println!(
        "model: {} ({:.1}B params, {} experts, top-{})",
        model.name,
        model.param_count() as f64 / 1e9,
        model.n_experts,
        model.top_k
    );

    // An ElasticMoE deployment over a 6-device cluster, starting on 4.
    let mut method = make_method("elastic", &model, 6)?;
    let initial =
        ParallelConfig::standard(2, model.tp, (0..4).collect())?;
    let target =
        ParallelConfig::standard(3, model.tp, (0..6).collect())?;

    // 2 rps of 2000-token prompts for two minutes; scale-up at t=45 s.
    let mut gen = WorkloadGen::new(WorkloadSpec {
        prompt_len: 2000,
        decode_min: 150,
        decode_max: 250,
        profile: RateProfile::Fixed(2.0),
        seed: 1,
    });
    let arrivals = gen.arrivals_until(120.0);
    println!("workload: {} requests over 120 s", arrivals.len());

    let slo = SloConfig::new(5.0, 1.5);
    let sim = ServingSim::new(
        CostModel::new(model.clone(), Timings::cloudmatrix()),
        slo,
    );
    let out = sim.run(
        method.as_mut(),
        &initial,
        arrivals,
        Trigger::Manual(vec![(45.0, target)]),
        120.0,
    )?;

    println!("\n== scaling event ==");
    for ev in &out.scaling_events {
        println!("  {}", ev.metrics.label());
        println!("  scale latency : {:.2} s", ev.ready_after);
        println!("  downtime      : {:.2} s", ev.metrics.downtime);
        println!("  peak memory   : {:.1} GB", ev.metrics.peak_gb());
        for (stage, t) in &ev.metrics.stages {
            println!("    {stage:<24} {t:>8.3} s");
        }
    }

    let w = out.recorder.window(0.0, out.end_time + 1e-6, &slo);
    println!("\n== serving quality ==");
    println!("  completed      : {}", w.completed);
    println!("  SLO attainment : {:.1}%", w.slo_attainment * 100.0);
    println!("  mean TTFT      : {:.3} s", w.mean_ttft);
    println!("  mean TPOT      : {:.4} s", w.mean_tpot);
    assert!(out.scaling_events[0].metrics.downtime == 0.0);
    println!("\nzero-downtime scale-up verified ✓");
    Ok(())
}
