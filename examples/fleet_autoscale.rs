//! Fleet autoscaling demo: two ElasticMoE replicas behind a
//! join-shortest-queue router face a 10x flash crowd. The hybrid fleet
//! policy absorbs the burst with seconds-scale vertical steps — no
//! whole-replica cold boot — then shrinks back after the crowd passes.
//!
//! Run: `cargo run --release --example fleet_autoscale`

use anyhow::Result;

use elastic_moe::config::model::dsv2_lite;
use elastic_moe::config::SloConfig;
use elastic_moe::coordinator::{
    FleetAction, FleetLimits, FleetPolicy, FleetSim, PolicyMode, Router,
};
use elastic_moe::device::Timings;
use elastic_moe::engine::CostModel;
use elastic_moe::experiments::common::elastic_with_opts;
use elastic_moe::hmm::control::HmmOptions;
use elastic_moe::imm::manager::ImmOptions;
use elastic_moe::scaling::ScalingMethod;
use elastic_moe::workload::{RateProfile, WorkloadGen, WorkloadSpec};

const REPLICA_MAX: usize = 8;

fn main() -> Result<()> {
    elastic_moe::util::logging::init();
    let model = dsv2_lite();
    let slo = SloConfig::scale_up_demo();

    let sim = FleetSim::new(
        CostModel::new(model.clone(), Timings::cloudmatrix()),
        slo,
        Router::JoinShortestQueue,
    );
    let mut policy = FleetPolicy::new(
        PolicyMode::Hybrid,
        FleetLimits {
            pool_devices: 12,
            replica_base: 2,
            replica_max: REPLICA_MAX,
            step: 2,
            min_replicas: 2,
        },
        slo,
    );
    policy.estimator.up_patience = 1;
    policy.estimator.cooldown = 10.0;
    policy.replica_cooldown = 10.0;

    // 0.8 rps baseline with a 10x crowd between t=60 and t=150.
    let horizon = 300.0;
    let mut gen = WorkloadGen::new(WorkloadSpec {
        prompt_len: 2000,
        decode_min: 100,
        decode_max: 150,
        profile: RateProfile::Burst {
            base: 0.8,
            factor: 10.0,
            start: 60.0,
            len: 90.0,
        },
        seed: 7,
    });
    let arrivals = gen.arrivals_until(horizon);
    println!(
        "fleet: 2x ElasticMoE replicas (2 devices each, 12-device pool)"
    );
    println!("workload: {} requests over {horizon} s (x10 flash crowd)", arrivals.len());

    let mut factory = |_i: usize| -> Result<Box<dyn ScalingMethod>> {
        Ok(Box::new(elastic_with_opts(
            &model,
            REPLICA_MAX,
            HmmOptions::default(),
            ImmOptions::default(),
        )) as Box<dyn ScalingMethod>)
    };
    let out = sim.run(&mut policy, &mut factory, 2, arrivals, horizon)?;

    println!("\n== fleet actions ==");
    for (t, a) in &out.actions {
        match a {
            FleetAction::VerticalUp { replica, to_devices } => println!(
                "  t={t:>6.1}s  replica {replica} vertical up -> {to_devices} devices"
            ),
            FleetAction::VerticalDown { replica, to_devices } => println!(
                "  t={t:>6.1}s  replica {replica} vertical down -> {to_devices} devices"
            ),
            FleetAction::AddReplica => {
                println!("  t={t:>6.1}s  add replica (cold boot)")
            }
            FleetAction::DrainReplica { replica } => {
                println!("  t={t:>6.1}s  drain replica {replica}")
            }
            FleetAction::Rebalance { replica } => println!(
                "  t={t:>6.1}s  replica {replica} expert rebalance (same devices)"
            ),
            FleetAction::Park { replica } => println!(
                "  t={t:>6.1}s  replica {replica} parked (weights DRAM-resident)"
            ),
            FleetAction::Unpark { replica } => println!(
                "  t={t:>6.1}s  replica {replica} unparked (DRAM-warm fast boot)"
            ),
            FleetAction::Hold => {}
        }
    }

    println!("\n== scaling transitions ==");
    for ev in &out.scaling_events {
        println!(
            "  {}  in {:.2} s (downtime {:.2} s)",
            ev.metrics.label(),
            ev.ready_after,
            ev.metrics.downtime
        );
    }

    let att = out.recorder.attainment_by_arrival(0.0, horizon, &slo);
    println!("\n== results ==");
    println!("  completed      : {}", out.recorder.count());
    println!("  SLO attainment : {:.1}%", att * 100.0);
    println!("  cold boots     : {}", out.cold_boots);
    println!("  device timeline: {:?}", out.device_timeline);
    assert_eq!(out.cold_boots, 0, "the burst must be absorbed vertically");
    println!("\nflash crowd absorbed with vertical steps only ✓");
    Ok(())
}
