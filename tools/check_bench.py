#!/usr/bin/env python3
"""Bench regression gate: compare fresh BENCH_*.json against committed
baselines.

Usage:
    python3 tools/check_bench.py <baseline_dir> <current_dir>

Compares the higher-is-better throughput metrics in BENCH_hotpath.json
and BENCH_serve.json (written by `repro bench --json`) against the
baselines committed under rust/benches/baselines/. A drop of more than
MAX_DROP (25%) in any gated metric fails the build.

Baselines that carry `"provisional": true` are advisory: regressions are
reported but the gate exits 0. This is how a fresh baseline is seeded —
commit it provisional, let CI print the comparison for a few runs, then
copy a representative artifact over the baseline and drop the flag.

Deliberately dependency-free (stdlib json only): CI runs it with the
system python3, and it must never be the reason a build needs a
package manager.
"""

import json
import sys

MAX_DROP = 0.25

# Gated metrics per file: dotted paths into the JSON document. All are
# higher-is-better (events/sec, tokens/sec, attainment fraction).
GATED = {
    "BENCH_hotpath.json": [
        "event_core.events_per_sec",
        "windowed_reference.events_per_sec",
    ],
    "BENCH_serve.json": [
        "steady.tokens_per_sec",
        "steady.slo_attainment",
    ],
}

# Informational-only metrics (printed, never gated): lower-is-better or
# too noisy for a hard threshold.
INFORMATIONAL = {
    "BENCH_hotpath.json": [
        "speedup",
        "telemetry_overhead.overhead_frac",
    ],
    "BENCH_serve.json": [
        "steady.ttft_p99_s",
        "scale_up_latency_s.elastic",
        "scale_up_latency_s.cold",
    ],
}


def lookup(doc, path):
    cur = doc
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur if isinstance(cur, (int, float)) else None


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except FileNotFoundError:
        return None
    except json.JSONDecodeError as e:
        print(f"error: {path} is not valid JSON: {e}")
        sys.exit(2)


def main():
    if len(sys.argv) != 3:
        print(__doc__)
        sys.exit(2)
    baseline_dir, current_dir = sys.argv[1], sys.argv[2]

    failures = []
    advisory_only = True

    for fname, paths in GATED.items():
        base = load(f"{baseline_dir}/{fname}")
        cur = load(f"{current_dir}/{fname}")
        if base is None:
            print(f"{fname}: no committed baseline — skipping (commit one "
                  f"under {baseline_dir}/ with \"provisional\": true)")
            continue
        if cur is None:
            failures.append(f"{fname}: current artifact missing in "
                            f"{current_dir}/ (did `repro bench --json` run?)")
            continue

        provisional = bool(base.get("provisional", False))
        if not provisional:
            advisory_only = False
        mode = "advisory (provisional baseline)" if provisional else "gated"
        print(f"{fname} [{mode}]")

        for path in paths:
            b, c = lookup(base, path), lookup(cur, path)
            if b is None:
                print(f"  {path}: not in baseline — skipped")
                continue
            if c is None:
                msg = f"{fname}: {path} missing from current artifact"
                print(f"  {path}: MISSING from current run")
                if not provisional:
                    failures.append(msg)
                continue
            drop = 0.0 if b <= 0 else (b - c) / b
            status = "ok"
            if drop > MAX_DROP:
                status = f"REGRESSION ({drop * 100.0:.1f}% drop)"
                if not provisional:
                    failures.append(
                        f"{fname}: {path} dropped {drop * 100.0:.1f}% "
                        f"({b:g} -> {c:g}), limit {MAX_DROP * 100.0:.0f}%")
            print(f"  {path}: {b:g} -> {c:g}  [{status}]")

        for path in INFORMATIONAL.get(fname, []):
            b, c = lookup(base, path), lookup(cur, path)
            if b is not None and c is not None:
                print(f"  {path}: {b:g} -> {c:g}  [info]")

    if failures:
        print()
        for f in failures:
            print(f"FAIL: {f}")
        sys.exit(1)
    if advisory_only:
        print()
        print("all baselines provisional — advisory run, gate passes. "
              "Bless a real baseline by copying a CI artifact over "
              "rust/benches/baselines/ and removing \"provisional\".")
    print("bench gate: OK")


if __name__ == "__main__":
    main()
